"""Declarative operator-graph API: typed-port validation at bind time,
Pipeline launch/stream/serve bit-identity with the legacy imperative
protocol, deprecation shims, ragged-tail executables, profile statistics."""
import warnings

import numpy as np
import pytest

import jax

from repro.core import (CLapp, Data, GraphError, KData, Node, Pipeline, Port,
                        PortError, Process, ProfileParameters, XData,
                        compile_cache_stats)
from repro.processes import (FFT, ComplexElementProd, SimpleMRIRecon,
                             XImageSum)
from repro.processes.coil_combine import CombineParams
from repro.processes.complex_elementprod import ComplexElementProdParams
from repro.processes.fft import FFTParams


class AddConst(Process):
    def apply(self, views, aux, params):
        c = params if params is not None else 1.0
        return {k: v + c for k, v in views.items()}


class Scale(Process):
    def apply(self, views, aux, params):
        return {k: v * params for k, v in views.items()}


class AddAux(Process):
    ports = {"in": Port(), "out": Port(),
             "bias": Port(aux=True, names=("img",))}

    def apply(self, views, aux, params):
        return {k: v + aux["bias"]["img"] for k, v in views.items()}


@pytest.fixture
def app():
    return CLapp().init()


def _xdata(rng, shape=(6, 5)):
    return XData({"img": rng.standard_normal(shape).astype(np.float32)})


# ---------------------------------------------------------------------------
# wiring + validation (must reject at bind/build time, not at launch)
# ---------------------------------------------------------------------------

def test_bind_rejects_unknown_port(app):
    with pytest.raises(PortError, match="no input or aux port"):
        AddAux(app).bind(nope=Data({"img": np.zeros((2, 2), np.float32)}))


def test_bind_validates_concrete_aux_data(app):
    # aux port requires an array named 'img'
    with pytest.raises(PortError, match="missing required arrays"):
        AddAux(app).bind(bias=Data({"wrong": np.zeros((2, 2), np.float32)}))


def test_bind_validates_concrete_input_data(app):
    bad = Data({"kdata": np.zeros((2, 2), np.float32)})  # not complex
    with pytest.raises(PortError, match="dtype"):
        ComplexElementProd(app).bind(infile=bad)


def test_pipeline_rejects_unknown_edge_at_composition(app):
    fft = FFT(app).bind(outfile="x")
    with pytest.raises(GraphError, match="no upstream node produces"):
        Pipeline(app) | fft | XImageSum(app).bind(infile="typo_edge")


def test_pipeline_rejects_duplicate_producer(app):
    with pytest.raises(GraphError, match="two producers"):
        (Pipeline(app)
         | AddConst(app).bind(outfile="e")
         | Scale(app).bind(infile="e", outfile="e"))


def test_build_rejects_spec_mismatch_before_any_compile(app, rng):
    """A mis-wired graph fails port validation in build() with NO side
    effects — nothing is compiled, nothing is registered."""
    pipe = Pipeline(app) | XImageSum(app).bind(params=CombineParams())
    h0, m0 = compile_cache_stats()
    n_data = len(app.data_handles)
    with pytest.raises(PortError, match="missing required arrays"):
        pipe.build(_xdata(rng))               # XImageSum needs 'kdata', 4-D
    h1, m1 = compile_cache_stats()
    assert (h1, m1) == (h0, m0), "validation must not compile anything"
    assert len(app.data_handles) == n_data, "validation must not register"


def test_build_rejects_rank_mismatch(app):
    bad = KData({"kdata": np.zeros((2, 3, 4), np.complex64),  # 3-D, needs 4
                 "sensitivity_maps": np.zeros((3, 4), np.complex64)})
    pipe = Pipeline(app) | XImageSum(app)
    with pytest.raises(PortError, match="ndim"):
        pipe.build(bad)


def test_from_graph_detects_cycle(app):
    a = AddConst(app).bind(infile="x", outfile="y")
    b = Scale(app).bind(infile="y", outfile="x")
    with pytest.raises(GraphError, match="cycle|exactly one input"):
        Pipeline.from_graph(app, [a, b])


def test_from_graph_rejects_multiple_anonymous_inputs(app):
    # two nodes leaving 'in' anonymous cannot be addressed by a run()
    # mapping; named input edges (a fan-in graph) are fine — see
    # tests/test_joins.py for the multi-input contract
    a = AddConst(app)
    b = Scale(app).bind(params=2.0)
    with pytest.raises(GraphError, match="anonymous input"):
        Pipeline.from_graph(app, [a.bind(outfile="y"), b])


def test_from_graph_accepts_multiple_named_inputs(app, rng):
    a = AddConst(app).bind(infile="in1", outfile="y", params=1.0)
    b = Scale(app).bind(infile="in2", outfile="z", params=3.0)
    pipe = Pipeline.from_graph(app, [a, b], output="z")
    assert set(pipe.input_edges) == {"in1", "in2"}
    d1, d2 = _xdata(rng), _xdata(rng)
    out = pipe.run({"in1": d1, "in2": d2})
    np.testing.assert_allclose(out.get_ndarray(0).host,
                               d2.get_ndarray(0).host * 3.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# execution: linear pipelines, DAGs, auto-wiring
# ---------------------------------------------------------------------------

def test_linear_pipeline_matches_manual_math(app, rng):
    base = rng.standard_normal((6, 5)).astype(np.float32)
    pipe = (Pipeline(app)
            | AddConst(app).bind(params=1.5)
            | Scale(app).bind(params=-2.0))
    out = pipe.run(XData({"img": base.copy()}))
    np.testing.assert_allclose(out.get_ndarray(0).host, (base + 1.5) * -2.0,
                               rtol=1e-6)


def test_pipeline_run_reuses_compiled_executable(app, rng):
    """Second run() with a fresh input Data must not recompile (the
    paper's zero-per-iteration-overhead property)."""
    pipe = Pipeline(app) | AddConst(app).bind(params=2.0)
    first = pipe.run(_xdata(rng, (7, 3)))
    h0, m0 = compile_cache_stats()
    d2 = _xdata(rng, (7, 3))
    second = pipe.run(d2)
    h1, m1 = compile_cache_stats()
    assert m1 == m0, "repeat run must not trace/compile again"
    np.testing.assert_allclose(second.get_ndarray(0).host,
                               d2.get_ndarray(0).host + 2.0, rtol=1e-6)
    assert first is second, "output Data is the registered output edge"


def test_aux_port_broadcast(app, rng):
    bias = rng.standard_normal((4, 4)).astype(np.float32)
    pipe = (Pipeline(app)
            | AddAux(app).bind(bias=XData({"img": bias})))
    d = _xdata(rng, (4, 4))
    out = pipe.run(d)
    np.testing.assert_allclose(out.get_ndarray(0).host,
                               d.get_ndarray(0).host + bias, rtol=1e-6)


def test_from_graph_fork_and_order_independence(app, rng):
    """Nodes arrive shuffled; from_graph topologically sorts them.  The
    fork (Scale reads the graph input edge, not AddConst's output) must be
    honoured — same wiring as the imperative forked-chain test."""
    base = rng.standard_normal((5, 5)).astype(np.float32)
    add = AddConst(app).bind(infile="src", outfile="plus1", params=1.0)
    scale = Scale(app).bind(infile="src", outfile="tripled", params=3.0)
    pipe = Pipeline.from_graph(app, [scale, add], output="tripled")
    out = pipe.run(XData({"img": base.copy()}))
    np.testing.assert_allclose(out.get_ndarray(0).host, base * 3.0,
                               rtol=1e-6)

    series = Pipeline.from_graph(
        app, [Scale(app).bind(infile="mid", outfile="done", params=3.0),
              AddConst(app).bind(infile="src2", outfile="mid", params=1.0)],
        output="done")
    out2 = series.run(XData({"img": base.copy()}))
    np.testing.assert_allclose(out2.get_ndarray(0).host, (base + 1.0) * 3.0,
                               rtol=1e-6)


def test_handle_bound_input_and_output(app, rng):
    """Explicit DataHandle bindings are honoured: the registered Data ARE
    the pipeline's input/output buffers (paper addData semantics)."""
    d_in = _xdata(rng, (4, 6))
    d_out = XData(d_in, copy_values=False)
    h_in, h_out = app.addData(d_in), app.addData(d_out)
    pipe = (Pipeline(app)
            | Scale(app).bind(infile=h_in, outfile=h_out, params=2.0))
    out = pipe.run()                       # no inputs: the handle is bound
    assert out is d_out, "results must land in the handle-bound Data"
    np.testing.assert_allclose(d_out.get_ndarray(0).host,
                               d_in.get_ndarray(0).host * 2.0, rtol=1e-6)
    # handle-bound output with a mismatched layout is rejected at build
    h_bad = app.addData(XData({"img": np.zeros((3, 3), np.float32)}))
    bad = Pipeline(app) | Scale(app).bind(outfile=h_bad, params=2.0)
    with pytest.raises(PortError, match="output"):
        bad.build(_xdata(rng, (4, 6)))


def test_fused_pipeline_matches_staged(app, rng):
    base = rng.standard_normal((6, 6)).astype(np.float32)

    def build(fuse):
        pipe = Pipeline(app, fuse=fuse) \
            | AddConst(app).bind(params=0.5) | Scale(app).bind(params=4.0)
        return pipe.run(XData({"img": base.copy()})).get_ndarray(0).host

    np.testing.assert_allclose(build(False), build(True), rtol=1e-6)


# ---------------------------------------------------------------------------
# acceptance: all three modes bit-identical to the legacy protocol
# ---------------------------------------------------------------------------

FRAMES, COILS, H, W = 4, 4, 64, 64   # vmapped FFT is bitwise-stable here


def _mri_inputs(n):
    rng = np.random.default_rng(7)
    smaps = (rng.standard_normal((COILS, H, W))
             + 1j * rng.standard_normal((COILS, H, W))).astype(np.complex64)
    out = []
    for i in range(n):
        r = np.random.default_rng(50 + i)
        k = (r.standard_normal((FRAMES, COILS, H, W))
             + 1j * r.standard_normal((FRAMES, COILS, H, W))).astype(np.complex64)
        out.append(KData({"kdata": k, "sensitivity_maps": smaps}))
    return out


def test_three_modes_bit_identical_to_legacy(app):
    """ISSUE 3 acceptance: Pipeline.run == legacy init()/launch() for
    SimpleMRIRecon, bitwise, in launch / stream(batch>1) / serve."""
    inputs = _mri_inputs(5)

    # legacy imperative reference, one launch per input
    d_in = _mri_inputs(1)[0]
    d_out = XData({"xdata": np.zeros(d_in.x_shape(), np.complex64)})
    h_in, h_out = app.addData(d_in), app.addData(d_out)
    legacy = SimpleMRIRecon(app, in_place=False)
    legacy.in_handle, legacy.out_handle = h_in, h_out
    legacy.init()
    want = []
    for src in inputs:
        for dst, s in zip(d_in, src):
            dst.set_host(s.host)
        app.host2device(h_in)
        legacy.launch()
        app.device2Host(h_out)
        want.append(d_out.get_ndarray(0).host.copy())

    # declarative: same operators, explicit graph
    pipe = (Pipeline(app)
            | FFT(app).bind(infile="kspace", outfile="xspace",
                            params=FFTParams("backward", var="kdata"))
            | ComplexElementProd(app).bind(
                params=ComplexElementProdParams(conjugate=True))
            | XImageSum(app).bind(params=CombineParams()))

    got_launch = [
        pipe.run(src).get_ndarray(0).host.copy() for src in inputs]
    got_stream = pipe.run(inputs, mode="stream", batch=2, sync=True)
    prof = ProfileParameters(enable=True)
    got_serve = pipe.run(inputs, mode="serve", batch=2, profile=prof)

    for i in range(len(inputs)):
        # launch mode runs one unbatched program per input — the exact
        # executable the legacy path compiles — and stays bitwise.
        np.testing.assert_array_equal(got_launch[i], want[i],
                                      err_msg=f"launch[{i}]")
        # stream/serve dispatch BATCHED programs (batch=2): XLA fuses the
        # fft→elementprod→sum chain differently under the extra leading
        # axis, reassociating the complex mults/adds.  Observed drift tops
        # out near 2.5e-5 relative (~2e-6 absolute) on CPU — numerical
        # noise, not a semantic divergence — so the batched modes assert
        # allclose at rtol=1e-4, and bitwise against each other below.
        np.testing.assert_allclose(
            got_stream[i].get_ndarray(0).host, want[i],
            rtol=1e-4, atol=1e-5, err_msg=f"stream[{i}]")
        np.testing.assert_allclose(
            got_serve[i].get_ndarray(0).host, want[i],
            rtol=1e-4, atol=1e-5, err_msg=f"serve[{i}]")
        # both batched modes run the SAME compiled program: bitwise equal.
        np.testing.assert_array_equal(
            got_serve[i].get_ndarray(0).host,
            got_stream[i].get_ndarray(0).host,
            err_msg=f"serve[{i}] vs stream[{i}]")
    assert len(prof.samples) == len(inputs), "one latency per request"
    assert all(s > 0 for s in prof.samples)
    assert prof.p99() >= prof.p50() > 0

    # the composite process is itself a valid single pipeline node
    solo = Pipeline(app) | SimpleMRIRecon(app, in_place=False).bind()
    got_solo = solo.run(inputs[0])
    np.testing.assert_array_equal(got_solo.get_ndarray(0).host, want[0])


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_legacy_setters_bit_identical_and_warn_exactly_once(app, rng):
    base = rng.standard_normal((6, 5)).astype(np.float32)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        d_in = XData({"img": base.copy()})
        d_out = XData(d_in, copy_values=False)
        h_in, h_out = app.addData(d_in), app.addData(d_out)
        p = Scale(app)
        p.set_in_handle(h_in)           # deprecated protocol
        p.set_out_handle(h_out)
        p.set_launch_parameters(2.5)
        p.init()
        p.launch()
        app.device2Host(h_out)
        legacy = d_out.get_ndarray(0).host.copy()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, "the legacy sequence must warn exactly once"
    assert "bind" in str(dep[0].message)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pipe = Pipeline(app) | Scale(app).bind(params=2.5)
        new = pipe.run(XData({"img": base.copy()})).get_ndarray(0).host
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)], \
        "the declarative path must not warn"
    np.testing.assert_array_equal(new, legacy)


def test_camelcase_aliases_also_warn(app, rng):
    d = _xdata(rng)
    h = app.addData(d)
    p = AddConst(app)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p.setInHandle(h)
        p.setOutHandle(h)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1


# ---------------------------------------------------------------------------
# ragged-tail executable (ROADMAP open item)
# ---------------------------------------------------------------------------

def _wired_scale(app, shape):
    d_in = XData({"img": np.zeros(shape, np.float32)})
    d_out = XData(d_in, copy_values=False)
    p = Scale(app)
    p.in_handle, p.out_handle = app.addData(d_in), app.addData(d_out)
    p.set_launch_parameters(3.0)
    return p


def test_ragged_tail_compiles_second_executable(app, rng):
    """9 items at batch=8: waste 7/8 > 0.5 -> the tail runs through a
    second executable compiled for 1 row (one extra cache miss), and the
    results still match the per-item math."""
    shape = (3, 17)                       # unique shape: fresh cache entries
    p = _wired_scale(app, shape)
    datasets = [XData({"img": rng.standard_normal(shape).astype(np.float32)})
                for _ in range(9)]
    h0, m0 = compile_cache_stats()
    outs = p.stream(datasets, batch=8, sync=True)
    h1, m1 = compile_cache_stats()
    assert m1 - m0 == 2, "main batched program + tail program"
    assert len(outs) == 9
    for d, o in zip(datasets, outs):
        np.testing.assert_allclose(o.get_ndarray(0).host,
                                   d.get_ndarray(0).host * 3.0, rtol=1e-6)
    # same tail size again: both executables come from the cache
    h2, m2 = compile_cache_stats()
    p.stream(datasets, batch=8, sync=True)
    h3, m3 = compile_cache_stats()
    assert m3 == m2, "repeat stream compiles nothing new"


def test_small_waste_still_pads(app, rng):
    """10 items at batch=4: waste 2/4 <= 0.5 -> the tail is padded by
    repetition (no second executable, exactly one compile)."""
    shape = (5, 13)
    p = _wired_scale(app, shape)
    datasets = [XData({"img": rng.standard_normal(shape).astype(np.float32)})
                for _ in range(10)]
    h0, m0 = compile_cache_stats()
    outs = p.stream(datasets, batch=4, sync=True)
    h1, m1 = compile_cache_stats()
    assert m1 - m0 == 1, "padding path must not compile a tail program"
    for d, o in zip(datasets, outs):
        np.testing.assert_allclose(o.get_ndarray(0).host,
                                   d.get_ndarray(0).host * 3.0, rtol=1e-6)


def test_tail_threshold_one_disables_tail_compile(app, rng):
    shape = (2, 29)
    p = _wired_scale(app, shape)
    datasets = [XData({"img": rng.standard_normal(shape).astype(np.float32)})
                for _ in range(9)]
    h0, m0 = compile_cache_stats()
    p.stream(datasets, batch=8, sync=True, tail_waste_threshold=1.0)
    h1, m1 = compile_cache_stats()
    assert m1 - m0 == 1, "threshold >= 1.0 always pads (pre-tail behaviour)"


# ---------------------------------------------------------------------------
# serving loop
# ---------------------------------------------------------------------------

def test_server_dynamic_batching_and_redrain(app, rng):
    shape = (4, 9)
    pipe = Pipeline(app) | Scale(app).bind(params=-1.5)
    server = pipe.serve(batch=4)
    datasets = [XData({"img": rng.standard_normal(shape).astype(np.float32)})
                for _ in range(6)]
    rids = [server.submit(d) for d in datasets]
    assert rids == list(range(6)) and server.pending == 6
    responses = server.drain()
    assert server.pending == 0 and server.served == 6
    assert server.launches == 2, "6 requests at batch=4 -> two launches"
    by_rid = {r.rid: r for r in responses}
    for rid, d in zip(rids, datasets):
        r = by_rid[rid]
        r.data.sync_to_host()
        np.testing.assert_allclose(r.data.get_ndarray(0).host,
                                   d.get_ndarray(0).host * -1.5, rtol=1e-6)
        assert r.latency_s > 0
    # the server keeps serving: a second wave reuses the compiled program
    h0, m0 = compile_cache_stats()
    more = [XData({"img": rng.standard_normal(shape).astype(np.float32)})
            for _ in range(3)]
    rids2 = [server.submit(d) for d in more]
    assert rids2 == [6, 7, 8]
    resp2 = server.drain()
    h1, m1 = compile_cache_stats()
    assert m1 == m0, "steady-state serving never recompiles"
    assert {r.rid for r in resp2} == {6, 7, 8}


def test_server_rejects_wrong_layout(app, rng):
    pipe = Pipeline(app) | Scale(app).bind(params=2.0)
    server = pipe.serve(batch=2)
    server.submit(_xdata(rng, (6, 5)))
    with pytest.raises(PortError, match="layout"):
        server.submit(_xdata(rng, (3, 3)))


# ---------------------------------------------------------------------------
# ProfileParameters statistics (satellite: no division by zero)
# ---------------------------------------------------------------------------

def test_profile_parameters_zero_samples_is_nan():
    prof = ProfileParameters(enable=True)   # launch() never profiled
    assert np.isnan(prof.mean())
    assert np.isnan(prof.percentile(50))
    assert np.isnan(prof.p50()) and np.isnan(prof.p99())


def test_profile_parameters_statistics():
    prof = ProfileParameters(enable=True)
    for s in (1.0, 2.0, 3.0, 10.0):
        prof.record(s)
    assert prof.mean() == 4.0
    assert prof.p50() == 2.5
    assert prof.p99() <= 10.0
    disabled = ProfileParameters(enable=False)
    disabled.record(5.0)                    # ignored when disabled
    assert np.isnan(disabled.mean())
