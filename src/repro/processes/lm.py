"""Autoregressive decode as first-class Pipeline processes.

The model zoo (:mod:`repro.models`) speaks pytrees: ``init_cache`` returns a
nested dict of KV/recurrent-state leaves, ``prefill``/``decode_step`` take
and return that tree.  The Pipeline world speaks arena-backed :class:`Data`:
named NDArrays packed into one device blob.  This module is the bridge — it
flattens the cache tree into arena entries (:class:`TreeCodec`) and wraps
the model's serve entry points as typed-port :class:`Process` es, so decode
runs through the SAME graph/residency/donation machinery as every other
workload:

* **decode state as one persistent arena Data** — ``token`` (B,1) i32,
  ``positions`` (B,) i32, ``active`` (B,) i32, plus every flattened cache
  leaf.  The Data is marked :attr:`~repro.core.data.Data.persistent`:
  ``Pipeline.build`` keeps it device-resident even though it sits on the
  step graph's input AND output edge, so each step's result is stamped
  ``Coherence.DEVICE_RESIDENT`` and the cache never round-trips the host.
* **:class:`DecodeStep`** — one greedy decode step over the whole batch,
  bound in-place (``infile == outfile`` == the state handle) so the
  compiled program *donates* the previous step's blob to XLA: step-to-step
  the cache moves zero bytes and allocates nothing new.
* **:class:`PrefillProcess`** — prompt -> fresh decode state (cache built
  inside the traced program; for encoder-decoder models the audio frames
  ride in on an optional second input port).
* **:class:`WhisperEncode` / :class:`WhisperPrefill`** — the encoder and
  the decoder-side prefill as separate graph nodes joined on an ``enc``
  edge: a real fan-in Pipeline (frames -> encoder ~ tokens -> decoder
  prefill) whose internal edge is device-resident and donated.
* **:class:`CacheSplice` / :class:`SlotRelease`** — continuous-batching
  primitives: splice a single-row prefill into one slot of the batched
  state / retire a finished slot, both wired in-place on the state handle
  (donation, not copies).  :class:`repro.serve.pipeline.LMServer` drives
  them.

:class:`DecodeSession` packages the full-batch loop (used by
``benchmarks/lm_step.py`` and the decode tests); per-slot continuous
batching lives in :class:`repro.serve.pipeline.LMServer`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.app import CLapp
from repro.core.data import Data
from repro.core.graph import Pipeline
from repro.core.process import (Port, Process, ProfileParameters,
                                current_compile_mesh)
from repro.launch.mesh import mesh_axis, model_axis_size


class TreeCodec:
    """Stable pytree <-> named-array bridge for one tree *structure*.

    Names are derived from the tree paths (``jax.tree_util.keystr``) with a
    fixed prefix, so the same codec maps any tree of the same structure —
    batch-1 row caches and batch-B full caches share one codec."""

    def __init__(self, tree: Any, prefix: str = ""):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        self.treedef = treedef
        self.names: Tuple[str, ...] = tuple(
            prefix + jax.tree_util.keystr(path) for path, _ in flat)

    def flatten(self, tree: Any) -> Dict[str, jax.Array]:
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.names):
            raise ValueError(
                f"tree has {len(leaves)} leaves, codec expects "
                f"{len(self.names)}")
        return dict(zip(self.names, leaves))

    def unflatten(self, named: Dict[str, jax.Array]) -> Any:
        return jax.tree_util.tree_unflatten(
            self.treedef, [named[n] for n in self.names])


def _abstract_cache(model, batch: int, max_len: int,
                    enc_len: Optional[int] = None):
    """Shape/dtype skeleton of ``model.init_cache`` without allocating."""
    if model.cfg.family == "encdec":
        if enc_len is None:
            raise ValueError("encoder-decoder models need enc_len")
        return jax.eval_shape(
            lambda: model.init_cache(batch, max_len, enc_len))
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def weights_data(params: Any, prefix: str = "w") -> Tuple[Data, TreeCodec]:
    """Flatten a params tree into one arena-backed Data (the static
    ``weights`` aux of every decode process) plus its codec."""
    codec = TreeCodec(params, prefix=prefix)
    named = codec.flatten(params)
    return Data({n: np.asarray(v) for n, v in named.items()}), codec


def decode_state_data(model, batch: int, max_len: int,
                      enc_len: Optional[int] = None,
                      ) -> Tuple[Data, TreeCodec]:
    """Spec-only persistent decode-state Data: sampling bookkeeping
    (``token``/``positions``/``active``) + every flattened cache leaf.
    Marked persistent/device-resident — the KV-cache-as-arena contract."""
    cache = _abstract_cache(model, batch, max_len, enc_len)
    codec = TreeCodec(cache, prefix="cache")
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "positions": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "active": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    for name, leaf in zip(codec.names, jax.tree_util.tree_leaves(cache)):
        specs[name] = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
    state = Data.from_specs(specs)
    state.persistent = True
    state.residency = "device"
    return state, codec


class _LMProcess(Process):
    """Shared plumbing: model + weights/cache codecs + a static key that
    separates compiled programs per architecture (two models with equal
    arena layouts must not share an executable)."""

    def __init__(self, app, model, wcodec: TreeCodec, ccodec: TreeCodec, *,
                 max_len: int, tag: str):
        super().__init__(app)
        self.model = model
        self.wcodec = wcodec
        self.ccodec = ccodec
        self.max_len = max_len
        self.set_launch_parameters((tag, repr(model.cfg), max_len))

    def _weights(self, aux):
        return self.wcodec.unflatten(aux["weights"])

    def _state_from(self, logits, cache, prompt_len: int):
        """Greedy-sample the prefill logits and assemble a fresh state."""
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, 1)
        b = token.shape[0]
        out = {"token": token,
               "positions": jnp.full((b,), prompt_len, jnp.int32),
               "active": jnp.ones((b,), jnp.int32)}
        out.update(self.ccodec.flatten(cache))
        return out


class PrefillProcess(_LMProcess):
    """Prompt tokens -> fresh decode state (cache initialised and prefilled
    inside the one traced program; greedy first token sampled on device).
    Encoder-decoder models bind the optional ``frames`` input port."""

    ports = {"in": Port(names=("tokens",), dtype=jnp.integer,
                        doc="prompt token ids (B, S)"),
             "frames": Port(optional=True,
                            doc="audio frame embeddings (B, T_enc, D), "
                                "encoder-decoder families only"),
             "out": Port(names=("token", "positions", "active")),
             "weights": Port(aux=True, doc="flattened model params")}

    def __init__(self, app, model, wcodec, ccodec, *, max_len: int):
        super().__init__(app, model, wcodec, ccodec, max_len=max_len,
                         tag="prefill")

    def apply(self, views, aux, params):
        w = self._weights(aux)
        tokens = views["tokens"]
        b, s = tokens.shape
        if self.model.cfg.family == "encdec":
            if "frames" not in aux:
                raise ValueError(
                    "encoder-decoder prefill needs the 'frames' port bound")
            frames = aux["frames"]["frames"]
            cache = self.model.init_cache(b, self.max_len, frames.shape[1])
            logits, cache = self.model.prefill(w, frames, tokens, cache)
        else:
            cache = self.model.init_cache(b, self.max_len)
            logits, cache = self.model.prefill(w, tokens, cache)
        return self._state_from(logits, cache, s)


class WhisperEncode(Process):
    """Audio frames -> encoder states, as its own graph node (the fan-in
    showcase: its ``enc`` output edge is internal — device-resident and
    donated to the decoder prefill that joins on it)."""

    ports = {"in": Port(names=("frames",), doc="frame embeddings (B,T,D)"),
             "out": Port(names=("enc",)),
             "weights": Port(aux=True)}

    def __init__(self, app, model, wcodec: TreeCodec):
        super().__init__(app)
        self.model = model
        self.wcodec = wcodec
        self.set_launch_parameters(("whisper_encode", repr(model.cfg)))

    def apply(self, views, aux, params):
        w = self.wcodec.unflatten(aux["weights"])
        return {"enc": self.model.encode(w, views["frames"])}


class WhisperPrefill(_LMProcess):
    """Decoder-side prefill from precomputed encoder states: joins the
    ``enc`` edge produced by :class:`WhisperEncode` (cross-attention K/V
    are computed here and land in the cache)."""

    ports = {"in": Port(names=("tokens",), dtype=jnp.integer),
             "enc": Port(names=("enc",), doc="encoder states (B, T_enc, D)"),
             "out": Port(names=("token", "positions", "active")),
             "weights": Port(aux=True)}

    def __init__(self, app, model, wcodec, ccodec, *, max_len: int):
        super().__init__(app, model, wcodec, ccodec, max_len=max_len,
                         tag="whisper_prefill")

    def apply(self, views, aux, params):
        w = self._weights(aux)
        tokens = views["tokens"]
        enc = aux["enc"]["enc"]
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.max_len, enc.shape[1])
        logits, cache = self.model.prefill_from_enc(w, enc, tokens, cache)
        return self._state_from(logits, cache, s)


class DecodeStep(_LMProcess):
    """One greedy decode step over the whole batch, in place on the state.

    Matches the legacy ``ServeEngine.step`` math exactly: decode every row
    at ``pos = positions.max()`` (inactive rows keep re-feeding their last
    token; the per-position cache masks stale entries), then advance only
    the active rows.

    Compiled under a mesh whose ``model`` axis is non-trivial, the step is
    ``shard_map``-partitioned over decode **slots** (the ``slot`` logical
    axis, :data:`repro.launch.mesh.LOGICAL_AXES`): each model-group member
    decodes its strip of rows + cache, with the one cross-slot quantity —
    the shared position scalar — reduced by an exact integer ``pmax``, so
    the partitioned step is bit-identical to the 1D one.  No-op when the
    mesh is 1D, the slot count does not divide, or any cache leaf's batch
    axis cannot be identified."""

    ports = {"in": Port(names=("token", "positions", "active")),
             "out": Port(names=("token", "positions", "active")),
             "weights": Port(aux=True)}

    def __init__(self, app, model, wcodec, ccodec, *, max_len: int):
        super().__init__(app, model, wcodec, ccodec, max_len=max_len,
                         tag="decode_step")

    @staticmethod
    def _slot_axis(leaf, b: int) -> Optional[int]:
        """Batch (slot) axis of one cache leaf: 0 for per-row leaves, 1 for
        stacked-layer ``(L, B, ...)`` leaves — the same heuristic as
        ``_splice_row`` (ambiguous when L == B; axis 0 wins there)."""
        if leaf.ndim >= 1 and leaf.shape[0] == b:
            return 0
        if leaf.ndim >= 2 and leaf.shape[1] == b:
            return 1
        return None

    def apply(self, views, aux, params):
        w = self._weights(aux)
        token = views["token"]
        positions = views["positions"]
        active = views["active"]
        cache = self.ccodec.unflatten(views)

        def step(w, token, positions, active, cache, pos):
            logits, cache = self.model.decode_step(w, token, pos, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, 1)
            live = active[:, None] > 0
            return (jnp.where(live, nxt, token), positions + active,
                    active, cache)

        mesh = current_compile_mesh()
        ax = mesh_axis("slot")          # mesh axis the slot dim is bound to
        b = int(token.shape[0])
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        slot_axes = [self._slot_axis(leaf, b) for leaf in leaves]
        nm = model_axis_size(mesh) if ax == "model" else 1
        if nm > 1 and b % nm == 0 and all(a is not None for a in slot_axes):
            from jax.experimental.shard_map import shard_map
            P = jax.sharding.PartitionSpec
            cache_specs = tuple(
                P(*([None] * a + [ax])) for a in slot_axes)

            def body(w, token, positions, active, *leaves):
                cache = jax.tree_util.tree_unflatten(treedef, leaves)
                pos = jax.lax.pmax(jnp.max(positions), ax).astype(jnp.int32)
                t, p, act, cache = step(w, token, positions, active,
                                        cache, pos)
                return (t, p, act) + tuple(jax.tree_util.tree_leaves(cache))

            outs = shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(ax, None), P(ax), P(ax)) + cache_specs,
                out_specs=(P(ax, None), P(ax), P(ax)) + cache_specs,
                check_rep=False)(w, token, positions, active, *leaves)
            token, positions, active = outs[0], outs[1], outs[2]
            cache = jax.tree_util.tree_unflatten(treedef, outs[3:])
        else:
            pos = jnp.max(positions).astype(jnp.int32)
            token, positions, active, cache = step(
                w, token, positions, active, cache, pos)
        out = {"token": token, "positions": positions, "active": active}
        out.update(self.ccodec.flatten(cache))
        return out


def _splice_row(full: jax.Array, row: jax.Array, slot) -> jax.Array:
    """Insert a 1-row leaf into slot ``slot`` of the batched leaf — the
    legacy ``ServeEngine._splice`` heuristic (batch axis is 0 for leaves
    whose leading axis differs, 1 for stacked-layer leaves), extended to
    the rank-1 bookkeeping arrays."""
    if full.ndim == 1 or (row.ndim >= 2 and full.shape[1:] == row.shape[1:]
                          and full.shape[0] != row.shape[0]):
        return jax.lax.dynamic_update_slice_in_dim(full, row, slot, axis=0)
    return jax.lax.dynamic_update_slice_in_dim(full, row, slot, axis=1)


class CacheSplice(Process):
    """Continuous-batching admission: splice a single-row prefilled state
    (the ``row`` aux, batch 1) into slot ``slot`` of the batched persistent
    state.  Wired in place (``in`` == ``out`` == the state handle) so the
    old state blob is donated, not copied.  ``slot`` is a launch parameter:
    one cached executable per slot."""

    ports = {"in": Port(names=("token", "positions", "active")),
             "out": Port(names=("token", "positions", "active")),
             "row": Port(aux=True, doc="batch-1 state from a row prefill")}

    def __init__(self, app, slot: int = 0):
        super().__init__(app)
        self.set_slot(slot)

    def set_slot(self, slot: int) -> None:
        self.set_launch_parameters(("cache_splice", int(slot)))

    def apply(self, views, aux, params):
        slot = int(params[1])
        row = aux["row"]
        return {name: _splice_row(full, row[name], slot)
                for name, full in views.items()}


class SlotRelease(Process):
    """Retire slot ``slot``: zero its ``active`` flag on device (freezing
    its position and token exactly like the legacy host-side bookkeeping)
    while passing the rest of the state through in place."""

    ports = {"in": Port(names=("token", "positions", "active")),
             "out": Port(names=("token", "positions", "active"))}

    def __init__(self, app, slot: int = 0):
        super().__init__(app)
        self.set_slot(slot)

    def set_slot(self, slot: int) -> None:
        self.set_launch_parameters(("slot_release", int(slot)))

    def apply(self, views, aux, params):
        slot = int(params[1])
        out = dict(views)
        out["active"] = jax.lax.dynamic_update_slice_in_dim(
            views["active"], jnp.zeros((1,), jnp.int32), slot, axis=0)
        return out


class DecodeSession:
    """Full-batch decode through the Pipeline stack: one prefill graph
    (the whisper encoder→decoder fan-in for encoder-decoder models), then
    a single in-place :class:`DecodeStep` node launched per token.

    The state Data is persistent: after the one zero-state upload folded
    into the first launch, every step donates the previous blob and stamps
    the result ``DEVICE_RESIDENT`` — ``step()`` reads back only the (B, 1)
    token view.  ``benchmarks/lm_step.py`` measures this path; per-slot
    continuous batching is :class:`repro.serve.pipeline.LMServer`."""

    def __init__(self, app: CLapp, model, params, *, batch: int,
                 max_len: int, enc_len: Optional[int] = None):
        self.app = app
        self.model = model
        self.batch = batch
        self.max_len = max_len
        self.encdec = model.cfg.family == "encdec"
        if self.encdec and enc_len is None:
            raise ValueError("encoder-decoder models need enc_len")
        wdata, self.wcodec = weights_data(params)
        self.weights_h = app.addData(wdata)     # uploaded once
        self.state, self.ccodec = decode_state_data(
            model, batch, max_len, enc_len)
        self.state_h = app.addData(self.state, to_device=False)
        if self.encdec:
            enc_node = WhisperEncode(app, model, self.wcodec).bind(
                infile="frames", outfile="enc", weights=self.weights_h)
            pre_node = WhisperPrefill(
                app, model, self.wcodec, self.ccodec,
                max_len=max_len).bind(
                    infile="tokens", outfile=self.state_h,
                    enc="enc", weights=self.weights_h)
            self.prefill_pipe = Pipeline.from_graph(
                app, [enc_node, pre_node])
        else:
            self.prefill_pipe = Pipeline(app) | PrefillProcess(
                app, model, self.wcodec, self.ccodec,
                max_len=max_len).bind(
                    infile="tokens", outfile=self.state_h,
                    weights=self.weights_h)
        self.decode_pipe = Pipeline(app) | DecodeStep(
            app, model, self.wcodec, self.ccodec, max_len=max_len).bind(
                infile=self.state_h, outfile=self.state_h,
                weights=self.weights_h)

    def tokens(self) -> np.ndarray:
        """Device -> host copy of the (B, 1) current-token view (the only
        per-step readback; the cache itself never leaves the device)."""
        return np.asarray(self.state.device_view("token")).copy()

    def prefill(self, tokens: np.ndarray, frames: Optional[np.ndarray] = None,
                profile: Optional[ProfileParameters] = None) -> np.ndarray:
        """Run the prefill graph for the whole batch; returns the greedy
        first tokens (B, 1)."""
        td = Data({"tokens": np.asarray(tokens, np.int32)})
        if self.encdec:
            inputs: Any = {"tokens": td,
                           "frames": Data({"frames": np.asarray(
                               frames, np.float32)})}
        else:
            inputs = td
        self.prefill_pipe.run(inputs, sync=False, profile=profile)
        return self.tokens()

    def step(self, profile: Optional[ProfileParameters] = None) -> np.ndarray:
        """One batched decode step (in-place, device-resident); returns
        the new (B, 1) tokens."""
        self.decode_pipe.run(None, sync=False, profile=profile)
        return self.tokens()
