"""qwen2-7b: 28L d=3584 28H (GQA kv=4, head 128) ff=18944 vocab=152064,
QKV bias.  [arXiv:2407.10671]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944, vocab=152064, qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab=128, param_dtype="float32", dtype="float32",
)
