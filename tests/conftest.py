import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# CPU in this container is slow and single-core; disable deadlines globally.
settings.register_profile(
    "repro", deadline=None, max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
