"""The FrontDoor serving control plane: priority admission with
backpressure, replica routing, health/metrics.

Covers the PR-8 subsystem end to end: admission overflow policies
(block / reject / shed) under contention, priority dispatch ordering,
deadline expiry, router policy selection (incl. the profile-weighted
split on a skewed pool), unhealthy-replica exclusion + probe recovery,
metrics counter/gauge/histogram correctness and Prometheus rendering,
the PipelineServer close/LMServer validation satellites, and bit-identity
of results routed through the control plane vs. a direct PipelineServer.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import CLapp, Pipeline, Process, XData
from repro.serve import (AdmissionRejected, CallableReplica, FrontDoor,
                         Metrics, PipelineReplica, PriorityClass, Router)
from repro.serve.control import Counter, Gauge, Histogram


@pytest.fixture
def app():
    return CLapp().init()


def _img(rng, shape=(6, 5)):
    return XData({"img": rng.standard_normal(shape).astype(np.float32)})


class Scale(Process):
    def apply(self, views, aux, params):
        return {k: v * params for k, v in views.items()}


def _echo(name, **kw):
    return CallableReplica(name, lambda p: p, **kw)


def _drain_statuses(fd, timeout=10.0):
    outs = fd.drain(timeout=timeout)
    return {o.rid: o.status for o in outs}, outs


# ---------------------------------------------------------------------------
# admission: overflow policies under contention
# ---------------------------------------------------------------------------

def _gated_frontdoor(capacity, overflow, **kw):
    """A FrontDoor whose single replica blocks on an event.  Two plug
    requests occupy the service slot and the one-batch-ahead inbox, so
    every later submit lands in the admission queue deterministically."""
    gate = threading.Event()

    def fn(p):
        gate.wait(10.0)
        return p

    fd = FrontDoor([CallableReplica("r", fn, max_batch=1)],
                   capacity=capacity, overflow=overflow, **kw)
    plugs = [fd.submit("plug-0", priority="interactive")]
    time.sleep(0.08)                  # worker takes it off the inbox
    plugs.append(fd.submit("plug-1", priority="interactive"))
    time.sleep(0.08)                  # dispatcher refills the inbox
    assert fd.queue_depth == 0
    return fd, gate, plugs


def test_reject_policy_full_queue():
    fd, gate, plugs = _gated_frontdoor(2, "reject")
    try:
        a = fd.submit("a")
        b = fd.submit("b")                # queue now at capacity (2)
        with pytest.raises(AdmissionRejected) as exc:
            fd.submit("c")
        assert exc.value.reason == "full"
        assert exc.value.priority == "normal"
        gate.set()
        statuses, _ = _drain_statuses(fd)
        assert statuses == {r: "ok" for r in plugs + [a, b]}
        assert fd.metrics.counter(
            "frontdoor_requests_rejected_total").value(**{"class": "normal"}) == 1
    finally:
        gate.set()
        fd.close()


def test_block_policy_waits_for_room_then_times_out():
    fd, gate, plugs = _gated_frontdoor(1, "block", block_timeout_s=0.15)
    try:
        fd.submit("a")                    # queue full (capacity 1)
        t0 = time.perf_counter()
        with pytest.raises(AdmissionRejected) as exc:
            fd.submit("b")                # blocks, then times out
        waited = time.perf_counter() - t0
        assert exc.value.reason == "blocked_timeout"
        assert waited >= 0.1, "block policy must actually wait"
        # with the gate open the queue drains and a blocked submit ADMITS
        gate.set()
        rid = fd.submit("c")
        statuses, _ = _drain_statuses(fd)
        assert statuses[rid] == "ok"
    finally:
        gate.set()
        fd.close()


def test_shed_policy_evicts_oldest_lowest_priority():
    fd, gate, plugs = _gated_frontdoor(2, "shed")
    try:
        r_old = fd.submit("old-batch", priority="batch")
        r_new = fd.submit("new-batch", priority="batch")
        # full queue + an interactive request: the OLDEST batch-class
        # entry is shed, the new request is admitted
        r_hi = fd.submit("urgent", priority="interactive")
        gate.set()
        statuses, outs = _drain_statuses(fd)
        assert statuses[r_old] == "shed"
        assert statuses[r_new] == "ok"
        assert statuses[r_hi] == "ok"
        shed = [o for o in outs if o.status == "shed"]
        assert shed[0].priority == "batch" and not shed[0].ok
        assert fd.metrics.counter(
            "frontdoor_requests_shed_total").value(**{"class": "batch"}) == 1
    finally:
        gate.set()
        fd.close()


def test_shed_never_evicts_more_urgent_work():
    fd, gate, plugs = _gated_frontdoor(2, "shed")
    try:
        fd.submit("hi-1", priority="interactive")
        fd.submit("hi-2", priority="interactive")
        # queue full of strictly-higher-priority work: the batch request
        # itself is refused instead of shedding urgent work
        with pytest.raises(AdmissionRejected) as exc:
            fd.submit("lowly", priority="batch")
        assert exc.value.reason == "higher_priority_only"
        gate.set()
        statuses, _ = _drain_statuses(fd)
        assert set(statuses.values()) == {"ok"}
    finally:
        gate.set()
        fd.close()


def test_closed_frontdoor_rejects_submissions():
    fd = FrontDoor([_echo("r")])
    fd.submit(1)
    fd.drain(timeout=5.0)
    fd.close()
    with pytest.raises(RuntimeError, match="closed"):
        fd.submit(2)
    fd.close()                            # idempotent


# ---------------------------------------------------------------------------
# priority ordering + deadline expiry
# ---------------------------------------------------------------------------

def test_priority_classes_dispatch_in_order():
    """Admit a full mix before starting the threads: dispatch (and hence
    a single serial replica's service order) follows class level, FIFO
    within a class."""
    order = []

    def record(p):
        order.append(p)
        return p

    fd = FrontDoor([CallableReplica("r", record, max_batch=1)],
                   capacity=16, auto_start=False)
    fd.submit("b1", priority="batch")
    fd.submit("n1", priority="normal")
    fd.submit("i1", priority="interactive")
    fd.submit("b2", priority="batch")
    fd.submit("i2", priority="interactive")
    assert fd.queue_depth == 5            # nothing moves before start()
    fd.start()
    statuses, _ = _drain_statuses(fd)
    fd.close()
    assert order == ["i1", "i2", "n1", "b1", "b2"]
    assert set(statuses.values()) == {"ok"}


def test_unknown_priority_class_rejected():
    fd = FrontDoor([_echo("r")], auto_start=False)
    with pytest.raises(ValueError, match="unknown priority class"):
        fd.submit(1, priority="vip")
    fd.close()


def test_deadline_expiry_drops_stale_requests():
    """A request older than its deadline completes as timed_out and is
    never launched."""
    launched = []
    gate = threading.Event()

    def fn(p):
        gate.wait(10.0)
        launched.append(p)
        return p

    fd = FrontDoor([CallableReplica("r", fn, max_batch=1)], capacity=16,
                   classes=[PriorityClass("rt", 0, deadline_s=0.05),
                            PriorityClass("bg", 1)],
                   default_class="bg")
    try:
        fd.submit("first", priority="bg")     # occupies the replica
        time.sleep(0.02)
        stale = fd.submit("stale", priority="rt")
        time.sleep(0.12)                      # rt deadline passes queued
        gate.set()
        statuses, _ = _drain_statuses(fd)
        assert statuses[stale] == "timed_out"
        assert "stale" not in launched
        assert fd.metrics.counter(
            "frontdoor_requests_timed_out_total").value(**{"class": "rt"}) == 1
    finally:
        gate.set()
        fd.close()


def test_per_request_deadline_overrides_class():
    gate = threading.Event()
    fd = FrontDoor([CallableReplica(
        "r", lambda p: (gate.wait(10.0), p)[1], max_batch=1)], capacity=16)
    try:
        fd.submit("first")
        time.sleep(0.02)
        stale = fd.submit("stale", deadline_s=0.03)
        fresh = fd.submit("fresh")            # no deadline
        time.sleep(0.1)
        gate.set()
        statuses, _ = _drain_statuses(fd)
        assert statuses[stale] == "timed_out"
        assert statuses[fresh] == "ok"
    finally:
        gate.set()
        fd.close()


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

def test_router_round_robin_cycles():
    a, b, c = _echo("a"), _echo("b"), _echo("c")
    r = Router("round-robin")
    picks = [r.pick([a, b, c]).name for _ in range(6)]
    assert picks == ["a", "b", "c", "a", "b", "c"]


def test_router_least_outstanding():
    a, b = _echo("a"), _echo("b")
    a.in_flight, b.in_flight = 3, 1
    assert Router("least-outstanding").pick([a, b]) is b


def test_router_profile_weighted_skew():
    """Smooth weighted RR over measured rates: a 3:1 skew yields an
    exactly 3:1 pick ratio over any aligned window."""
    fast, slow = _echo("fast"), _echo("slow")
    fast.set_rate(300.0)
    slow.set_rate(100.0)
    r = Router("profile")
    picks = [r.pick([fast, slow]).name for _ in range(40)]
    assert picks.count("fast") == 30 and picks.count("slow") == 10
    # cold replicas weigh in at the mean warm rate
    cold = _echo("cold")
    assert Router("profile").weights([fast, cold]) == [300.0, 300.0]
    assert Router("profile").weights([cold, _echo("cold2")]) == [1.0, 1.0]


def test_router_unknown_policy():
    with pytest.raises(ValueError, match="unknown routing policy"):
        Router("fastest-first")


def test_eager_profile_routing_splits_by_rate():
    """End to end: under eager dispatch the profile policy carves a
    burst across a skewed pool by measured items/sec.  The gate holds
    every routing decision at the seeded 3:1 rates — completions would
    otherwise refresh the EMA mid-dispatch."""
    gate = threading.Event()

    def up(p):
        gate.wait(10.0)
        return p

    fast = CallableReplica("fast", up)
    slow = CallableReplica("slow", up)
    fast.set_rate(300.0)
    slow.set_rate(100.0)
    fd = FrontDoor([fast, slow], capacity=40, policy="profile",
                   dispatch_ahead=None, auto_start=False)
    for i in range(40):
        fd.submit(i)
    fd.start()
    deadline = time.perf_counter() + 5.0
    while fd.queue_depth > 0 and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert fd.queue_depth == 0            # all 40 routed, none served yet
    gate.set()
    statuses, _ = _drain_statuses(fd)
    fd.close()
    assert set(statuses.values()) == {"ok"}
    assert fast.served == 30 and slow.served == 10


def test_demand_bounded_dispatch_holds_work_in_queue():
    """Default dispatch hands a replica at most max_batch requests ahead;
    the rest stay in the priority queue."""
    gate = threading.Event()
    fd = FrontDoor([CallableReplica(
        "r", lambda p: (gate.wait(10.0), p)[1], max_batch=2)], capacity=16)
    try:
        for i in range(6):
            fd.submit(i)
        time.sleep(0.1)
        # 1 batch processing (up to 2) + at most 2 dispatched ahead
        assert fd.queue_depth >= 2
        gate.set()
        statuses, _ = _drain_statuses(fd)
        assert set(statuses.values()) == {"ok"}
    finally:
        gate.set()
        fd.close()


# ---------------------------------------------------------------------------
# health: unhealthy exclusion + probe recovery
# ---------------------------------------------------------------------------

def test_unhealthy_replica_excluded_then_recovers():
    state = {"broken": True}

    def flaky(p):
        if state["broken"]:
            raise RuntimeError("injected replica failure")
        return p + 100

    flk = CallableReplica("flaky", flaky, probe_payload=0)
    ok = CallableReplica("ok", lambda p: p + 100)
    fd = FrontDoor([flk, ok], capacity=16, policy="round-robin",
                   probe_interval_s=0.02, max_retries=3)
    try:
        rids = [fd.submit(i) for i in range(6)]
        statuses, outs = _drain_statuses(fd)
        # every request completed OK: the failing replica's work was
        # re-routed (requeued counter > 0), nothing crashed
        assert [statuses[r] for r in rids] == ["ok"] * 6
        assert all(o.result == o.rid + 100 for o in outs)
        assert fd.metrics.counter(
            "frontdoor_requests_requeued_total").value() > 0
        h = fd.health()
        assert h["ok"]                       # pool degraded, not down
        assert not h["replicas"]["flaky"]["healthy"]
        assert "injected" in h["replicas"]["flaky"]["last_error"]
        assert fd.metrics.gauge(
            "frontdoor_replica_healthy").value(replica="flaky") == 0.0
        # probe succeeds once the fault clears -> replica rejoins routing
        state["broken"] = False
        deadline = time.perf_counter() + 5.0
        while not flk.healthy and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert flk.healthy
        assert fd.health()["replicas"]["flaky"]["healthy"]
        rid = fd.submit(50)
        fd.submit(51)
        statuses, _ = _drain_statuses(fd)
        assert statuses[rid] == "ok"
        assert flk.served > 0                # it genuinely serves again
    finally:
        fd.close()


def test_whole_pool_down_completes_as_error_after_retries():
    def broken(p):
        raise RuntimeError("always down")

    fd = FrontDoor([CallableReplica("b", broken)], capacity=4,
                   probe_interval_s=0.01, max_retries=2)
    try:
        rid = fd.submit(1)
        statuses, outs = _drain_statuses(fd, timeout=10.0)
        assert statuses[rid] == "error"
        err = [o for o in outs if o.rid == rid][0]
        assert "always down" in repr(err.error)
        assert not fd.health()["ok"]
    finally:
        fd.close()


def test_close_with_down_pool_does_not_hang():
    def broken(p):
        raise RuntimeError("down")

    fd = FrontDoor([CallableReplica("b", broken, probe_payload=1)],
                   capacity=4, probe_interval_s=10.0, max_retries=100)
    fd.submit(1)
    t0 = time.perf_counter()
    fd.close(timeout=5.0)
    assert time.perf_counter() - t0 < 5.0
    outs = fd.collect()
    assert len(outs) == 1 and outs[0].status == "error"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    m = Metrics()
    c = m.counter("requests_total", "all requests")
    c.inc()
    c.inc(2, method="post")
    assert c.value() == 1 and c.value(method="post") == 2
    assert c.total() == 3
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    g = m.gauge("depth")
    g.set(7)
    assert g.value() == 7
    h = m.histogram("latency_seconds")
    for v in [0.01, 0.02, 0.03, 0.04]:
        h.observe(v, replica="r0")
    assert h.count(replica="r0") == 4
    assert h.percentile(50.0, replica="r0") == pytest.approx(0.025)
    # get-or-create returns the same object; kind clashes raise
    assert m.counter("requests_total") is c
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("requests_total")
    with pytest.raises(ValueError, match="invalid metric name"):
        m.counter("bad-name")


def test_metrics_render_prometheus_format():
    m = Metrics()
    m.counter("admitted_total", "requests admitted").inc(
        3, **{"class": "normal"})
    m.gauge("queue_depth").set(2)
    h = m.histogram("latency_seconds")
    h.observe(0.5, replica="r0")
    text = m.render()
    assert "# TYPE admitted_total counter" in text
    assert 'admitted_total{class="normal"} 3' in text
    assert "# HELP admitted_total requests admitted" in text
    assert "# TYPE queue_depth gauge" in text
    assert "queue_depth 2" in text
    assert "# TYPE latency_seconds summary" in text
    assert 'latency_seconds{replica="r0",quantile="0.5"} 0.5' in text
    assert 'latency_seconds{replica="r0",quantile="0.999"} 0.5' in text
    assert 'latency_seconds_count{replica="r0"} 1' in text
    assert 'latency_seconds_sum{replica="r0"} 0.5' in text
    assert text.endswith("\n")


def test_frontdoor_metrics_accounting():
    """Counters reconcile: admitted == completed + shed + timed_out over
    a mixed run, queue depth returns to 0, latency histogram has one
    sample per served request."""
    fd, gate, plugs = _gated_frontdoor(2, "shed")
    try:
        fd.submit(0, priority="batch")
        fd.submit(1, priority="batch")
        fd.submit(2, priority="interactive")     # sheds the oldest batch
        gate.set()
        _, outs = _drain_statuses(fd)
        m = fd.metrics
        admitted = m.counter("frontdoor_requests_admitted_total").total()
        completed = m.counter("frontdoor_requests_completed_total").total()
        shed = m.counter("frontdoor_requests_shed_total").total()
        assert admitted == 5 and completed == 4 and shed == 1
        assert m.gauge("frontdoor_queue_depth").value() == 0
        assert m.histogram("frontdoor_request_latency_seconds").count(
            replica="r") == 4
        assert m.counter("frontdoor_replica_dispatched_total").value(
            replica="r") == 4
        health = fd.health()
        assert health["queue_depth"] == 0 and health["outstanding"] == 0
        assert health["replicas"]["r"]["served"] == 4
        assert health["replicas"]["r"]["p50_ms"] > 0
    finally:
        gate.set()
        fd.close()


def test_replica_rate_self_calibrates():
    """Without seeding, completed batches feed the replica EMA — the
    profile signal warms itself exactly like PR 5's proportional split."""
    r = CallableReplica("r", lambda p: p)
    assert r.rate != r.rate                   # cold: nan
    fd = FrontDoor([r], capacity=8)
    try:
        for i in range(4):
            fd.submit(i)
        fd.drain(timeout=5.0)
        assert r.rate > 0
    finally:
        fd.close()


# ---------------------------------------------------------------------------
# control plane over real pipelines: bit-identity + CLapp.split
# ---------------------------------------------------------------------------

def test_routed_results_bit_identical_to_direct_server(app, rng):
    """The FrontDoor adds routing, not math: results routed through
    PipelineReplicas match a direct PipelineServer bitwise."""
    ds = [_img(rng) for _ in range(10)]

    pipe_direct = Pipeline(app) | Scale(app).bind(params=2.5)
    server = pipe_direct.serve(batch=4)
    rids = [server.submit(d) for d in ds]
    by_rid = {r.rid: r.data for r in server.drain()}
    want = [np.asarray(by_rid[r].device_view("img")) for r in rids]

    replicas = []
    for i in range(2):
        p = Pipeline(app) | Scale(app).bind(params=2.5)
        replicas.append(PipelineReplica(f"r{i}", p.serve(batch=4)))
    fd = FrontDoor(replicas, capacity=16, policy="round-robin")
    try:
        fids = [fd.submit(d) for d in ds]
        outs = {o.rid: o for o in fd.drain(timeout=30.0)}
        served_by = set()
        for fid, w in zip(fids, want):
            o = outs[fid]
            assert o.ok, o.error
            got = np.asarray(o.result.device_view("img"))
            np.testing.assert_array_equal(got, w)
            served_by.add(o.replica)
        assert served_by == {"r0", "r1"}, "round-robin must use the pool"
    finally:
        fd.close()


def test_pipeline_replica_probe_recovers_real_server(app, rng):
    """A PipelineReplica with a probe request recovers after its server
    heals (fault injected at the launch plan, as in the PR-4 tests)."""
    pipe = Pipeline(app) | Scale(app).bind(params=3.0)
    server = pipe.serve(batch=2)
    rep = PipelineReplica("r0", server, probe_request=_img(rng))
    fd = FrontDoor([rep], capacity=8, probe_interval_s=0.02, max_retries=2)
    try:
        rid = fd.submit(_img(rng))
        outs = fd.drain(timeout=30.0)
        assert outs[0].rid == rid and outs[0].ok

        def boom(items):
            raise RuntimeError("injected launch failure")
        server._plan.stack_group = boom                        # break it
        bad = fd.submit(_img(rng))
        deadline = time.perf_counter() + 5.0
        while rep.healthy and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert not rep.healthy
        del server._plan.stack_group                           # heal it
        statuses, _ = _drain_statuses(fd, timeout=30.0)
        assert statuses[bad] == "ok"
        assert rep.healthy
    finally:
        fd.close()


def test_clapp_split_partitions_devices(app):
    n = len(app.devices)
    parts = app.split(n)
    assert [len(p.devices) for p in parts] == [1] * n
    assert [p.device for p in parts] == list(app.devices)
    for p in parts:
        assert p.mesh is not None
        assert p.device_profiles is not app.device_profiles
    with pytest.raises(ValueError, match="at least one device"):
        app.split(n + 1)
    with pytest.raises(ValueError, match="n >= 1"):
        app.split(0)


def test_frontdoor_validation():
    with pytest.raises(ValueError, match="at least one replica"):
        FrontDoor([])
    with pytest.raises(ValueError, match="unique"):
        FrontDoor([_echo("a"), _echo("a")])
    with pytest.raises(ValueError, match="capacity"):
        FrontDoor([_echo("a")], capacity=0)
    with pytest.raises(ValueError, match="overflow"):
        FrontDoor([_echo("a")], overflow="drop-newest")
    with pytest.raises(ValueError, match="dispatch_ahead"):
        FrontDoor([_echo("a")], dispatch_ahead=0)
    with pytest.raises(ValueError, match="default class"):
        FrontDoor([_echo("a")], default_class="vip")


# ---------------------------------------------------------------------------
# satellites: PipelineServer close semantics, LMServer prompt validation
# ---------------------------------------------------------------------------

def test_pipeline_server_closed_raises_instead_of_hanging(app, rng):
    pipe = Pipeline(app) | Scale(app).bind(params=2.0)
    server = pipe.serve(batch=4, flush_timeout=0.02)
    server.submit(_img(rng))
    assert len(server.collect(1, timeout=30.0)) == 1
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(_img(rng))
    with pytest.raises(RuntimeError, match="closed"):
        server.drain()
    with pytest.raises(RuntimeError, match="closed"):
        server.collect(1, timeout=1.0)


def test_pipeline_server_close_idempotent_and_concurrent(app, rng):
    """close() twice (and from two threads at once) joins the worker
    exactly once; a close after a worker death reaps without raising."""
    pipe = Pipeline(app) | Scale(app).bind(params=2.0)
    server = pipe.serve(batch=4, flush_timeout=0.02)
    server.submit(_img(rng))
    server.collect(1, timeout=30.0)
    errors = []

    def closer():
        try:
            server.close()
        except BaseException as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=closer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    server.close()
    assert errors == []

    # close() after the background thread died from a launch failure
    server2 = pipe.serve(batch=4, flush_timeout=0.02)
    server2.submit(_img(rng))
    server2.collect(1, timeout=30.0)

    def boom(items):
        raise RuntimeError("injected launch failure")
    server2._plan.stack_group = boom
    server2.submit(_img(rng))
    with pytest.raises(RuntimeError, match="drain thread died"):
        server2.collect(1, timeout=30.0)
    server2.close()                         # reaps the dead thread quietly
    server2.close()


def test_pipeline_server_without_flush_timeout_unaffected_by_close(app, rng):
    """No background thread -> close() is a no-op and drain() keeps
    working (the Pipeline.run(mode='serve') path)."""
    pipe = Pipeline(app) | Scale(app).bind(params=2.0)
    server = pipe.serve(batch=4)
    server.close()
    rid = server.submit(_img(rng))
    resp = server.drain()
    assert [r.rid for r in resp] == [rid]


def test_lmserver_prompt_length_validated_up_front():
    from repro.models import build_model
    from repro.models.common import ArchConfig
    from repro.serve import LMServer, PromptTooLongError, SamplingConfig

    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=32, vocab=48, remat=False,
                     dtype="float32", param_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    server = LMServer(model, params, batch=1, max_len=8,
                      sampling=SamplingConfig(max_new_tokens=2))
    with pytest.raises(PromptTooLongError) as exc:
        server.submit(list(range(8)))       # max_len tokens: no decode room
    assert exc.value.prompt_len == 8 and exc.value.max_len == 8
    assert "max_len=8" in str(exc.value)
    assert isinstance(exc.value, ValueError)
    with pytest.raises(PromptTooLongError):
        server.submit([])                   # empty prompt
    assert server.queue == [] and server.results == []  # nothing queued
    rid = server.submit(list(range(1, 8)))  # max_len - 1 fits
    outs = server.run()
    assert len(outs[rid]) == 2
