"""RWKV6 (Finch) WKV recurrence Pallas kernel.

The data-dependent-decay linear-attention update

    s_t = diag(exp(-exp(w_t))) . s_{t-1} + k_t^T v_t
    o_t = r_t . (s_{t-1} + diag(u) k_t^T v_t)

is sequential in t but embarrassingly parallel over (batch, heads).  TPU
adaptation: grid (B, H, T/bt) with the (D, D) state held in VMEM scratch
across time-blocks (the minor grid dim), a `fori_loop` over the bt in-tile
steps, and all outer products shaped (D, D) = (64, 64) -> MXU/VPU friendly
and far under VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import kernel
from . import ref
from .common import interpret_mode, pad_dim, round_up


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_ref, *, block_t: int, t_len: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (D,)

    def step(t, _):
        inside = ti * block_t + t < t_len

        @pl.when(inside)
        def _():
            rt = r_ref[0, t, 0].astype(jnp.float32)   # (D,)
            kt = k_ref[0, t, 0].astype(jnp.float32)
            vt = v_ref[0, t, 0].astype(jnp.float32)
            wt = w_ref[0, t, 0].astype(jnp.float32)
            s = s_ref[...]
            kv = kt[:, None] * vt[None, :]            # (D, D)
            out = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
            o_ref[0, t, 0] = out.astype(o_ref.dtype)
            decay = jnp.exp(-jnp.exp(wt))
            s_ref[...] = s * decay[:, None] + kv

        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(ti == nt - 1)
    def _final():
        sT_ref[0, 0] = s_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
         state: Optional[jax.Array] = None, block_t: int = 64
         ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B, T, H, D); u: (H, D); state: (B, H, D, D) f32 or None.
    Returns (out (B,T,H,D), final_state (B,H,D,D))."""
    b, t, h, d = r.shape
    if state is None:
        state = jnp.zeros((b, h, d, d), dtype=jnp.float32)
    bt = min(block_t, round_up(t, 8))
    tp = round_up(t, bt)
    rp, kp2, vp, wp = (pad_dim(x, 1, tp) for x in (r, k, v, w))

    grid = (b, h, tp // bt)
    seq_spec = pl.BlockSpec((1, bt, 1, d), lambda bi, hi, ti: (bi, ti, hi, 0))
    u_spec = pl.BlockSpec((1, d), lambda bi, hi, ti: (hi, 0))
    s_spec = pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0))
    out, s_final = pl.pallas_call(
        functools.partial(_wkv6_kernel, block_t=bt, t_len=t),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, s_spec],
        out_specs=[seq_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, tp, h, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret_mode(),
    )(rp, kp2, vp, wp, u, state)
    return out[:, :t], s_final


kernel("wkv6", ref=ref.wkv6)(wkv6)
