#!/usr/bin/env python
"""Docs smoke check: every intra-repo markdown link must resolve.

Scans the repo's markdown files for ``[text](target)`` links and verifies
that each relative target (external ``http(s)://``/``mailto:`` links and
pure ``#anchor`` self-references are skipped) exists on disk, relative to
the file containing the link.  Exits non-zero listing every dangling
link — CI runs this in the docs-smoke job so the guides cannot rot.

    python tools/check_doc_links.py [root]
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) — but not images' URL part differences; images ![...](...)
# are matched too (the target must still exist).  Nested parens are not
# used in this repo's docs.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def doc_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github",
                                    "node_modules")]
        for f in filenames:
            if f.endswith(".md"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def check(root: str) -> list[str]:
    errors = []
    for path in doc_files(root):
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, root)}: dangling link "
                    f"'{target}' (resolved to {os.path.relpath(resolved, root)})")
    return errors


def main() -> int:
    root = os.path.abspath(sys.argv[1]) if len(sys.argv) > 1 else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors = check(root)
    n_files = len(doc_files(root))
    if errors:
        print(f"doc link check FAILED ({len(errors)} dangling):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"doc link check OK ({n_files} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
