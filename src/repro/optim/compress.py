"""Error-feedback int8 gradient compression for the data-parallel axis.

Distributed-optimization trick for 1000+-node scale: before the DP
all-reduce, each worker quantizes its local gradient to int8 with a
per-tensor scale; the quantization error is kept in a local error-feedback
buffer and added back the next step, so the compression bias telescopes away
(Karimireddy et al., 2019).  4x less DP traffic at the cost of one extra
f32 buffer per tensor.

With GSPMD auto-collectives the reduce is implicit, so the compressed path
is expressed with ``shard_map`` over the DP axes: quantize -> psum(int32) ->
dequantize.  ``compressed_dp_mean`` is the drop-in replacement used by the
train step when ``compress_grads=True``; on a 1-sized axis it degrades to
quantize/dequantize (still exercising the EF math, which is how the CPU
tests validate it).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_int8_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(grad, error_buffer) -> (q_int8, scale, new_error)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def ef_int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def dp_mean_compressed(g: jax.Array, err: jax.Array, axis_names) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: int8 all-reduce-mean over ``axis_names``."""
    q, scale, new_err = ef_int8_compress(g, err)
    # sum int8 payloads in int32 (the collective payload is the int8 tensor;
    # scales are tiny and reduced in f32)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
    ssum = jax.lax.psum(scale, axis_names)
    n = 1
    for ax in (axis_names if isinstance(axis_names, tuple) else (axis_names,)):
        n *= jax.lax.axis_size(ax)
    # each worker used its own scale; the unbiased reconstruction averages
    # dequantized values — approximate with mean scale (standard EF-SGD impl)
    mean = qsum.astype(jnp.float32) * (ssum / n) / n
    return mean, new_err
