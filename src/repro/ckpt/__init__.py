from .checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    cleanup,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointCorruptError", "CheckpointManager", "cleanup",
           "latest_step", "restore_checkpoint", "save_checkpoint"]
