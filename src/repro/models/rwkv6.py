"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Time-mix: token-shift ddlerp (low-rank data-dependent interpolation of the
five r/k/v/w/g streams), the WKV6 recurrence (Pallas kernel or jnp-scan
oracle), per-head group norm, gated output.  Channel-mix: token-shifted
squared-ReLU MLP.  Decode state is O(1): two shift vectors + the (H, D, D)
WKV state per layer.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as kref
from . import layers as L
from .common import ArchConfig, KeyGen, MODEL, BATCH_AXES, Rules, dense_init, embed_init, constrain, scan_layers

TM_LORA = 32   # ddlerp low-rank dim
TD_LORA = 64   # decay low-rank dim


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    dh = cfg.rwkv_head_dim
    return cfg.d_model // dh, dh


def init_rwkv_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    kg = KeyGen(key)
    d, f = cfg.d_model, cfg.d_ff
    nh, dh = _heads(cfg)
    zeros = lambda *s: jnp.zeros(s, cfg.pdtype)
    return {
        "ln1": L.init_norm(cfg), "ln2": L.init_norm(cfg),
        "tm": {
            "maa_x": zeros(d), "maa_w": zeros(d), "maa_k": zeros(d),
            "maa_v": zeros(d), "maa_r": zeros(d), "maa_g": zeros(d),
            "tm_w1": dense_init(kg("tm_w1"), (d, 5 * TM_LORA), cfg.pdtype),
            "tm_w2": dense_init(kg("tm_w2"), (5, TM_LORA, d), cfg.pdtype),
            "decay": zeros(d),
            "td_w1": dense_init(kg("td_w1"), (d, TD_LORA), cfg.pdtype),
            "td_w2": dense_init(kg("td_w2"), (TD_LORA, d), cfg.pdtype),
            "u": dense_init(kg("u"), (nh, dh), jnp.float32),
            "w_r": dense_init(kg("w_r"), (d, d), cfg.pdtype),
            "w_k": dense_init(kg("w_k"), (d, d), cfg.pdtype),
            "w_v": dense_init(kg("w_v"), (d, d), cfg.pdtype),
            "w_g": dense_init(kg("w_g"), (d, d), cfg.pdtype),
            "w_o": dense_init(kg("w_o"), (d, d), cfg.pdtype),
            "gn_scale": jnp.ones((d,), cfg.pdtype),
            "gn_bias": jnp.zeros((d,), cfg.pdtype),
        },
        "cm": {
            "maa_k": zeros(d), "maa_r": zeros(d),
            "w_k": dense_init(kg("cm_k"), (d, f), cfg.pdtype),
            "w_v": dense_init(kg("cm_v"), (f, d), cfg.pdtype),
            "w_r": dense_init(kg("cm_r"), (d, d), cfg.pdtype),
        },
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1}; position 0 gets `last` (decode) or zeros."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _group_norm(x: jax.Array, scale, bias, nh: int, dh: int, eps: float = 64e-5):
    b, t, d = x.shape
    xg = x.reshape(b, t, nh, dh).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, t, d) * scale.astype(jnp.float32)
            + bias.astype(jnp.float32))


def time_mix(p, x, cfg: ArchConfig, wkv_fn, shift_in=None, wkv_state=None):
    """x: (B,T,D). Returns (out, new_shift (B,D), new_wkv_state)."""
    b, t, d = x.shape
    nh, dh = _heads(cfg)
    xprev = _shift(x, shift_in)
    sx = xprev - x
    xxx = x + sx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["tm_w1"]).reshape(b, t, 5, TM_LORA)
    mixes = jnp.einsum("btfl,fld->btfd", lora, p["tm_w2"])       # (B,T,5,D)
    xw = x + sx * (p["maa_w"] + mixes[:, :, 0])
    xk = x + sx * (p["maa_k"] + mixes[:, :, 1])
    xv = x + sx * (p["maa_v"] + mixes[:, :, 2])
    xr = x + sx * (p["maa_r"] + mixes[:, :, 3])
    xg = x + sx * (p["maa_g"] + mixes[:, :, 4])

    r = (xr @ p["w_r"]).reshape(b, t, nh, dh)
    k = (xk @ p["w_k"]).reshape(b, t, nh, dh)
    v = (xv @ p["w_v"]).reshape(b, t, nh, dh)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32))
    w = (p["decay"].astype(jnp.float32)
         + (jnp.tanh(xw @ p["td_w1"]) @ p["td_w2"]).astype(jnp.float32))
    w = w.reshape(b, t, nh, dh)

    out, new_state = wkv_fn(r, k, v, w, p["u"], wkv_state)
    out = out.reshape(b, t, d)
    out = _group_norm(out, p["gn_scale"], p["gn_bias"], nh, dh)
    out = (out * g).astype(cfg.adtype) @ p["w_o"]
    return out, x[:, -1, :], new_state


def channel_mix(p, x, cfg: ArchConfig, shift_in=None):
    xprev = _shift(x, shift_in)
    sx = xprev - x
    xk = x + sx * p["maa_k"]
    xr = x + sx * p["maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    k = constrain(k, BATCH_AXES, None, MODEL)
    kv = k @ p["w_v"]
    return jax.nn.sigmoid((xr @ p["w_r"]).astype(jnp.float32)).astype(cfg.adtype) * kv, x[:, -1, :]


class RWKV6Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def _wkv_fn(self):
        cfg = self.cfg
        if cfg.use_pallas:
            from repro.kernels.wkv6 import wkv6 as pallas_wkv6
            return lambda r, k, v, w, u, s: pallas_wkv6(r, k, v, w, u, s)
        return lambda r, k, v, w, u, s: kref.wkv6(r, k, v, w, u, s)

    def init_params(self, rng):
        cfg = self.cfg
        kg = KeyGen(rng)
        keys = jax.random.split(kg("layers"), cfg.n_layers)
        return {
            "embed": L.init_embed(kg("embed"), cfg),
            "ln0": L.init_norm(cfg),
            "layers": jax.vmap(lambda k: init_rwkv_layer(k, cfg))(keys),
            "final_norm": L.init_norm(cfg),
        }

    def _layer(self, lp, x, cfg, wkv_fn, state=None):
        st = state or {}
        h = L.apply_norm(lp["ln1"], x, cfg)
        tm_out, tm_shift, wkv_state = time_mix(
            lp["tm"], h, cfg, wkv_fn,
            st.get("tm_shift"), st.get("wkv"))
        x = x + tm_out
        h = L.apply_norm(lp["ln2"], x, cfg)
        cm_out, cm_shift = channel_mix(lp["cm"], h, cfg, st.get("cm_shift"))
        x = x + cm_out
        new_state = {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv_state}
        return x, new_state

    def hidden_states(self, params, tokens):
        cfg = self.cfg
        wkv_fn = self._wkv_fn()
        x = L.embed_tokens(params["embed"], tokens, cfg)
        x = L.apply_norm(params["ln0"], x, cfg)

        def body(xc, lp):
            xo, _ = self._layer(lp, xc, cfg, wkv_fn)
            xo = constrain(xo, BATCH_AXES, None, None)
            return xo, ()

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = scan_layers(body_fn, x, params["layers"], unroll=cfg.unroll_layers)
        return L.apply_norm(params["final_norm"], x, cfg)

    def loss_fn(self, params, batch):
        logits = L.logits_from_hidden(
            params["embed"], self.hidden_states(params, batch["tokens"]), self.cfg)
        loss = L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return loss, {"loss": loss}

    # ------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        nh, dh = _heads(cfg)
        n, d = cfg.n_layers, cfg.d_model
        return {
            "tm_shift": jnp.zeros((n, batch, d), cfg.adtype),
            "cm_shift": jnp.zeros((n, batch, d), cfg.adtype),
            "wkv": jnp.zeros((n, batch, nh, dh, dh), jnp.float32),
        }

    def _run_cached(self, params, tokens, cache):
        cfg = self.cfg
        wkv_fn = self._wkv_fn()
        x = L.embed_tokens(params["embed"], tokens, cfg)
        x = L.apply_norm(params["ln0"], x, cfg)

        def body(xc, inp):
            lp, tm_s, cm_s, wkv_s = inp
            xo, ns = self._layer(lp, xc, cfg, wkv_fn,
                                 {"tm_shift": tm_s, "cm_shift": cm_s, "wkv": wkv_s})
            return xo, (ns["tm_shift"], ns["cm_shift"], ns["wkv"])

        body_fn = jax.checkpoint(body) if (cfg.remat and tokens.shape[1] > 1) else body
        x, (tm_s, cm_s, wkv_s) = scan_layers(
            body_fn, x,
            (params["layers"], cache["tm_shift"], cache["cm_shift"], cache["wkv"]),
            unroll=cfg.unroll_layers)
        x = L.apply_norm(params["final_norm"], x[:, -1:], cfg)
        logits = L.logits_from_hidden(params["embed"], x, cfg)
        return logits, {"tm_shift": tm_s, "cm_shift": cm_s,
                        "wkv": wkv_s.astype(jnp.float32)}

    def prefill(self, params, tokens, cache):
        return self._run_cached(params, tokens, cache)

    def decode_step(self, params, token, pos, cache):
        del pos  # recurrent: position-free
        return self._run_cached(params, token, cache)

    # ---------------------------------------------------------- sharding
    def partition_rules(self) -> Rules:
        lay: Rules = [
            (r"tm.*tm_w1|tm.*td_w1", P(None, MODEL)),
            (r"tm.*tm_w2", P(None, None, MODEL)),
            (r"tm.*td_w2", P(MODEL, None)),
            (r"tm.*w_r|tm.*w_k|tm.*w_v|tm.*w_g", P(None, MODEL)),
            (r"tm.*w_o", P(MODEL, None)),
            (r"tm.*'u'", P(MODEL, None)),
            (r"cm.*w_k", P(None, MODEL)),
            (r"cm.*w_v", P(MODEL, None)),
            (r"cm.*w_r", P(None, MODEL)),
        ]
        rules: Rules = [
            (r"embed.*embedding", P(MODEL, None)),
            (r"embed.*unembed", P(None, MODEL)),
        ]
        rules += [(rf"layers.*(?:{pat})", P(None, *spec)) for pat, spec in lay]
        return rules

    def cache_partition_rules(self) -> Rules:
        return [
            (r"tm_shift|cm_shift", P(None, BATCH_AXES, MODEL)),
            (r"wkv", P(None, BATCH_AXES, MODEL, None, None)),
        ]
