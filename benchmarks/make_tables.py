"""Render EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSONL results.

    PYTHONPATH=src python -m benchmarks.make_tables \
        results/dryrun_single.jsonl results/dryrun_multi.jsonl
"""
from __future__ import annotations

import json
import sys

from repro.configs import ARCH_IDS, SHAPES, cells


def load(path):
    best = {}
    try:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                best[(r["arch"], r["shape"])] = r
    except FileNotFoundError:
        pass
    return best


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def _fmt_t(s):
    if s is None or s != s or s == float("inf"):
        return "—"
    return f"{s * 1e6:.1f}µs"


def crossover_table(path=None):
    """Render the per-(kernel, layout) backend-calibration records from
    ``BENCH_pallas_fusion.json`` (the measured crossover points behind
    ``use_pallas="auto"``; see docs/kernels.md)."""
    import os
    if path is None:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_pallas_fusion.json")
    try:
        with open(path) as f:
            bench = json.load(f)
    except (FileNotFoundError, ValueError):
        print("\n### §Backend crossover: PENDING "
              "(run `python -m benchmarks.pallas_fusion`)\n")
        return
    print("\n### §Backend crossover (use_pallas=\"auto\" calibration, "
          f"device={bench.get('device', '?')})\n")
    print("| kernel | layout | chosen | t_pallas | t_xla | roofline bound "
          "| interpreted |")
    print("|--------|--------|--------|----------|-------|----------------"
          "|-------------|")
    for r in bench.get("crossover", []):
        import ast
        try:
            args = ast.literal_eval(r["layout"])[0]
            shapes = "·".join("x".join(map(str, a[1]))
                              for a in args if a[0] == "arr")
        except (ValueError, SyntaxError):
            shapes = r["layout"][:40]
        print(f"| {r['kernel']} | {shapes} | **{r['backend']}** "
              f"| {_fmt_t(r.get('t_pallas_s'))} | {_fmt_t(r.get('t_xla_s'))} "
              f"| {r.get('bound', '—')} | {'yes' if r.get('interpreted') else 'no'} |")
    for r in bench.get("layouts", []):
        print(f"| mriFusedRecon (end-to-end) "
              f"| {'x'.join(map(str, r['shape']))} "
              f"| **{r.get('auto_resolved_backend', '?')}** "
              f"| {_fmt_t(r.get('t_fused_s'))} | {_fmt_t(r.get('t_staged_s'))}"
              f" (staged) | — | no |")


def ckpt_io_table(path=None):
    """Render ``BENCH_ckpt_io.json``: legacy host-gather vs gather-free
    sharded checkpoint save/restore (see docs/checkpoint.md)."""
    import os
    if path is None:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_ckpt_io.json")
    try:
        with open(path) as f:
            bench = json.load(f)
    except (FileNotFoundError, ValueError):
        print("\n### §Checkpoint I/O: PENDING "
              "(run `python -m benchmarks.ckpt_io`)\n")
        return
    mesh = "x".join(str(v) for v in bench.get("mesh", {}).values())
    print(f"\n### §Checkpoint I/O ({bench.get('state_mb', 0):.1f}MB state, "
          f"mesh {mesh}, {len(bench.get('shard_files', []))} shard files, "
          f"gather-free={bench.get('sharded_save_gather_free')}"
          f"{', SMOKE sizes' if bench.get('smoke') else ''})\n")
    print("| format | save | restore | elastic restore | gather phase "
          "| shard-write phase |")
    print("|--------|------|---------|-----------------|--------------"
          "|-------------------|")
    for fmt in ("legacy", "sharded"):
        t = bench.get("timings", {}).get(fmt)
        if not t:
            continue
        print(f"| {fmt} | {_fmt_t(t['save_s'])} | {_fmt_t(t['restore_s'])} "
              f"| {_fmt_t(t['elastic_restore_s'])} "
              f"| {_fmt_t(t['gather_s']) if t['gather_s'] else '0 (none)'} "
              f"| {_fmt_t(t['shard_write_s']) if t['shard_write_s'] else '—'} |")


def main():
    single = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single.jsonl")
    multi = load(sys.argv[2] if len(sys.argv) > 2 else "results/dryrun_multi.jsonl")

    print("### §Dry-run (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512)\n")
    print("| arch | shape | kind | note | args/chip | temp/chip | multi-pod |")
    print("|------|-------|------|------|-----------|-----------|-----------|")
    for arch, shape, ok, why in cells(include_skips=True):
        if not ok:
            print(f"| {arch} | {shape} | — | **skipped**: {why} | — | — | — |")
            continue
        r = single.get((arch, shape))
        m = multi.get((arch, shape))
        if r is None or r.get("status") != "ok":
            print(f"| {arch} | {shape} | ? | PENDING | | | |")
            continue
        mem = r.get("memory", {})
        mp = "ok" if (m and m.get("status") == "ok") else "PENDING"
        print(f"| {arch} | {shape} | {r['kind']} | {r.get('note','')} "
              f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
              f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} | {mp} |")

    print("\n### §Roofline (single-pod, per chip; v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bound | "
          "useful FLOPs | MFU bound |")
    print("|------|-------|-----------|----------|--------------|-------|"
          "--------------|-----------|")
    for arch, shape, ok, why in cells(include_skips=False):
        r = single.get((arch, shape))
        if r is None or r.get("status") != "ok":
            continue
        f = r["roofline"]
        # recompute the collective term with ring-wire weights (all-reduce
        # moves 2x) from the stored breakdown, so old and new records render
        # consistently
        from repro.launch.roofline import ICI_BW, wire_bytes
        t_coll = wire_bytes(f.get("coll_breakdown", {})) / ICI_BW
        terms = {"compute": f["t_compute_s"], "memory": f["t_memory_s"],
                 "collective": t_coll}
        bound = max(terms, key=terms.get)
        mfu = f["model_flops"] / (max(terms.values()) * r["chips"] * 197e12) \
            if max(terms.values()) > 0 else float("nan")
        print(f"| {arch} | {shape} "
              f"| {f['t_compute_s']*1e3:.1f}ms | {f['t_memory_s']*1e3:.1f}ms "
              f"| {t_coll*1e3:.1f}ms | **{bound}** "
              f"| {f['useful_flops_ratio']*100:.0f}% "
              f"| {mfu*100:.2f}% |")

    crossover_table()
    ckpt_io_table()


if __name__ == "__main__":
    main()
