"""Mesh-sharded streaming scaling: throughput at 1/2/4/8 host devices.

The host-platform device count is locked at the first jax initialisation,
so each point runs in its own subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  Every child
reconstructs the same stack of synthetic multicoil K-space Data sets
through ``SimpleMRIRecon`` with ``stream(..., sharded=True)`` — the call
site is IDENTICAL at every device count; only ``CLapp.init()``'s device
selection changes, which is the paper's housekeeping promise at mesh
scale.

Forced host devices split one physical CPU, so wall-clock speedup is NOT
expected here — the benchmark demonstrates correct placement (every batch
sharded over all N devices) and records per-count throughput for hosts
where the devices are real.  Emits harness CSV rows, a ``BENCH {json}``
line, and ``BENCH_mesh_scaling.json`` next to this file.

    PYTHONPATH=src python -m benchmarks.mesh_scaling
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List

DEVICE_COUNTS = (1, 2, 4, 8)
FRAMES, COILS, H, W = 2, 2, 32, 32
N_DATASETS = 16
BATCH = 8
REPS = 5


def _child(n_devices: int) -> dict:
    """Run inside the forced-device subprocess: streamed sharded recon."""
    import jax
    import numpy as np

    from repro.core import CLapp, KData, XData

    from repro.processes import SimpleMRIRecon

    app = CLapp().init()
    assert len(app.devices) == n_devices, (
        f"expected {n_devices} forced devices, got {len(app.devices)}")

    rng = np.random.default_rng(0)
    smaps = (rng.standard_normal((COILS, H, W))
             + 1j * rng.standard_normal((COILS, H, W))).astype(np.complex64)
    datasets = []
    for i in range(N_DATASETS):
        r = np.random.default_rng(100 + i)
        k = (r.standard_normal((FRAMES, COILS, H, W))
             + 1j * r.standard_normal((FRAMES, COILS, H, W))).astype(np.complex64)
        datasets.append(KData({"kdata": k, "sensitivity_maps": smaps}))

    d_in = KData({"kdata": datasets[0].kdata.host.copy(),
                  "sensitivity_maps": smaps})
    d_out = XData({"xdata": np.zeros(d_in.x_shape(), np.complex64)})
    h_in, h_out = app.addData(d_in), app.addData(d_out)
    proc = SimpleMRIRecon(app, mode="staged", in_place=False)
    proc.set_in_handle(h_in)
    proc.set_out_handle(h_out)
    proc.init()

    def run():
        outs = proc.stream(datasets, batch=BATCH, sharded=True)
        jax.block_until_ready([o.device_blob for o in outs])
        return outs

    outs = run()                               # warmup (batched compile)
    used = set()
    for o in outs:
        used |= set(o.device_blob.devices())
    t = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        run()
        t = min(t, time.perf_counter() - t0)
    return {
        "devices": n_devices,
        "devices_used": len(used),
        "streamed_s": round(t, 5),
        "sets_per_s": round(N_DATASETS / t, 2),
    }


def rows() -> List[str]:
    points = []
    for n in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}").strip()
        src = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.mesh_scaling", "--child", str(n)],
            env=env, capture_output=True, text=True, timeout=600,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if r.returncode != 0:
            raise RuntimeError(
                f"mesh_scaling child (n={n}) failed:\n{r.stdout}\n{r.stderr}")
        points.append(json.loads(r.stdout.strip().splitlines()[-1]))

    base = points[0]["streamed_s"]
    out_rows = []
    for p in points:
        p["speedup_vs_1dev"] = round(base / p["streamed_s"], 3)
        out_rows.append(
            f"mesh_stream_{p['devices']}dev,"
            f"{p['streamed_s'] / N_DATASETS * 1e6:.1f},"
            f"devices_used={p['devices_used']};"
            f"sets_per_s={p['sets_per_s']};"
            f"speedup_vs_1dev={p['speedup_vs_1dev']}")

    bench = {
        "name": "mesh_scaling",
        "n_datasets": N_DATASETS, "batch": BATCH,
        "shape": [FRAMES, COILS, H, W],
        "points": points,
        "all_devices_used": all(
            p["devices_used"] == p["devices"] for p in points),
    }
    print("BENCH " + json.dumps(bench))
    out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_mesh_scaling.json")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)
    return out_rows


def main() -> None:
    if "--child" in sys.argv:
        n = int(sys.argv[sys.argv.index("--child") + 1])
        print(json.dumps(_child(n)))
        return
    print("name,us_per_call,derived")
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
