"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and
the dry-run must set XLA_FLAGS before that happens.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_data_mesh(devices: Sequence[jax.Device],
                   axis_names: Tuple[str, str] = ("data", "model"),
                   ) -> jax.sharding.Mesh:
    """An explicit-device ``(data, model)`` mesh: every given device on the
    ``data`` axis, ``model`` trivial.  This is the mesh :class:`repro.core.
    app.CLapp` builds over its *selected* devices (which may be a subset or
    reordering of ``jax.devices()``, so ``jax.make_mesh`` — which always
    takes the first N global devices — is not usable here)."""
    if not devices:
        raise ValueError("cannot build a mesh over zero devices")
    grid = np.array(devices, dtype=object).reshape(len(devices), 1)
    return jax.sharding.Mesh(grid, axis_names)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally, as a (data, model) mesh — used by the
    examples and tests on the single CPU device."""
    return make_data_mesh(jax.devices())
