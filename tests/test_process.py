"""Process semantics: init/launch split, zero-copy chaining, staged==fused,
compile cache, donation — the paper's §III-A.3 behaviours."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CLapp, Data, DeviceTraits, PlatformTraits, Process,
                        ProcessChain, ProfileParameters, SyncSource, XData,
                        compile_cache_stats)


class AddConst(Process):
    def apply(self, views, aux, params):
        c = params if params is not None else 1.0
        return {k: v + c for k, v in views.items()}


class Scale(Process):
    def apply(self, views, aux, params):
        return {k: v * params for k, v in views.items()}


@pytest.fixture
def app():
    return CLapp().init(PlatformTraits(), DeviceTraits())


def _data(rng, shape=(16, 16)):
    return XData({"img": rng.standard_normal(shape).astype(np.float32)})


def test_init_launch_split_and_overhead(app, rng):
    """init() pays compilation; launch() must be orders faster."""
    d_in, d_out = _data(rng), None
    h_in = app.addData(d_in)
    d_out = XData(d_in, copy_values=False)
    h_out = app.addData(d_out)
    p = AddConst(app)
    p.set_in_handle(h_in)
    p.set_out_handle(h_out)
    p.set_launch_parameters(2.5)
    t0 = time.perf_counter()
    p.init()
    t_init = time.perf_counter() - t0
    prof = ProfileParameters(enable=True)
    for _ in range(20):
        p.launch(prof)
    assert prof.mean() < t_init, "launch must be cheaper than init (plan baking)"
    app.device2Host(h_out)
    np.testing.assert_allclose(d_out.get_ndarray(0).host,
                               d_in.get_ndarray(0).host + 2.5, rtol=1e-6)


def test_chain_staged_equals_fused(app, rng):
    base = rng.standard_normal((8, 8)).astype(np.float32)
    results = {}
    for mode in ("staged", "fused"):
        d_in = XData({"img": base.copy()})
        d_mid = XData(d_in, copy_values=False)
        d_out = XData(d_in, copy_values=False)
        h_in, h_mid, h_out = (app.addData(x) for x in (d_in, d_mid, d_out))
        p1 = AddConst(app); p1.set_in_handle(h_in); p1.set_out_handle(h_mid)
        p1.set_launch_parameters(1.0)
        p2 = Scale(app); p2.set_in_handle(h_mid); p2.set_out_handle(h_out)
        p2.set_launch_parameters(3.0)
        chain = ProcessChain(app, [p1, p2], mode=mode)
        chain.init()
        chain.launch()
        app.device2Host(h_out)
        results[mode] = d_out.get_ndarray(0).host.copy()
    np.testing.assert_allclose(results["staged"], results["fused"], rtol=1e-6)
    np.testing.assert_allclose(results["staged"], (base + 1.0) * 3.0, rtol=1e-6)


def test_in_place_donation(app, rng):
    """out_handle == in_handle: the blob is donated, result lands in place."""
    d = _data(rng)
    orig = d.get_ndarray(0).host.copy()
    h = app.addData(d)
    p = AddConst(app)
    p.set_in_handle(h)
    p.set_out_handle(h)
    p.set_launch_parameters(5.0)
    p.init()
    p.launch()
    app.device2Host(h)
    np.testing.assert_allclose(d.get_ndarray(0).host, orig + 5.0, rtol=1e-6)


def test_compile_cache_hits(app, rng):
    """Same process class + same layout + same params = one compilation."""
    h0, m0 = compile_cache_stats()
    for _ in range(3):
        d_in = _data(rng)
        d_out = XData(d_in, copy_values=False)
        h_in, h_out = app.addData(d_in), app.addData(d_out)
        p = Scale(app)
        p.set_in_handle(h_in); p.set_out_handle(h_out)
        p.set_launch_parameters(2.0)
        p.init()
        p.launch()
    h1, m1 = compile_cache_stats()
    assert m1 - m0 == 1, "one miss (first init)"
    assert h1 - h0 == 2, "subsequent inits must hit the cache"


def test_parameter_change_triggers_reinit(app, rng):
    d_in = _data(rng)
    d_out = XData(d_in, copy_values=False)
    h_in, h_out = app.addData(d_in), app.addData(d_out)
    p = Scale(app)
    p.set_in_handle(h_in); p.set_out_handle(h_out)
    p.set_launch_parameters(2.0)
    p.init(); p.launch()
    app.device2Host(h_out)
    r1 = d_out.get_ndarray(0).host.copy()
    p.set_launch_parameters(4.0)   # paper: parameters may vary per call
    p.init(); p.launch()
    app.device2Host(h_out)
    r2 = d_out.get_ndarray(0).host.copy()
    np.testing.assert_allclose(r2, r1 * 2.0, rtol=1e-6)


def test_fused_chain_cache_distinguishes_wiring(app, rng):
    """Two chains with identical stages/params/layouts but different
    inter-stage wiring must not share one compiled executable."""
    base = rng.standard_normal((8, 8)).astype(np.float32)

    def build(series_wiring):
        d_in = XData({"img": base.copy()})
        d_mid = XData(d_in, copy_values=False)
        d_out = XData(d_in, copy_values=False)
        h_in, h_mid, h_out = (app.addData(x) for x in (d_in, d_mid, d_out))
        p1 = AddConst(app); p1.set_in_handle(h_in); p1.set_out_handle(h_mid)
        p1.set_launch_parameters(1.0)
        p2 = Scale(app)
        p2.set_in_handle(h_mid if series_wiring else h_in)
        p2.set_out_handle(h_out)
        p2.set_launch_parameters(3.0)
        chain = ProcessChain(app, [p1, p2], mode="fused")
        chain.init()
        chain.launch()
        app.device2Host(h_out)
        return d_out.get_ndarray(0).host.copy()

    series = build(True)     # p2 reads p1's output: (x + 1) * 3
    forked = build(False)    # p2 reads the chain input:  x * 3
    np.testing.assert_allclose(series, (base + 1.0) * 3.0, rtol=1e-6)
    np.testing.assert_allclose(forked, base * 3.0, rtol=1e-6)


def test_aux_rewire_after_init_takes_effect(app, rng):
    """Re-wiring an aux handle to a same-layout Data between launches is
    honoured without re-init (aux handles are read live, not snapshotted)."""
    class AddBias(Process):
        def apply(self, views, aux, params):
            return {k: v + aux["bias"]["img"] for k, v in views.items()}

    b1 = rng.standard_normal((4, 4)).astype(np.float32)
    b2 = rng.standard_normal((4, 4)).astype(np.float32)
    d_in = _data(rng, (4, 4))
    d_out = XData(d_in, copy_values=False)
    h_in, h_out = app.addData(d_in), app.addData(d_out)
    h_b1 = app.addData(XData({"img": b1}))
    h_b2 = app.addData(XData({"img": b2}))
    p = AddBias(app)
    p.set_in_handle(h_in); p.set_out_handle(h_out)
    p.set_aux_handle("bias", h_b1)
    p.init(); p.launch()
    app.device2Host(h_out)
    np.testing.assert_allclose(d_out.get_ndarray(0).host,
                               d_in.get_ndarray(0).host + b1, rtol=1e-6)
    p.set_aux_handle("bias", h_b2)   # same layout, no re-init
    p.launch()
    app.device2Host(h_out)
    np.testing.assert_allclose(d_out.get_ndarray(0).host,
                               d_in.get_ndarray(0).host + b2, rtol=1e-6)


def test_heterogeneous_data_single_transfer(app, rng):
    """Arbitrarily heterogeneous Data moves as ONE buffer (paper §III-A.2)."""
    d = Data({"vol": rng.standard_normal((2, 3, 4)).astype(np.float32),
              "mask": rng.integers(0, 2, (3, 4)).astype(np.uint8),
              "kspace": (rng.standard_normal((4, 4))
                         + 1j * rng.standard_normal((4, 4))).astype(np.complex64)})
    h = app.addData(d)
    assert d.device_blob is not None and d.device_blob.ndim == 1
    views = d.device_views()
    assert set(views) == {"vol", "mask", "kspace"}
    for name in views:
        np.testing.assert_array_equal(
            np.asarray(views[name]),
            np.asarray([a.host for a in d if a.name == name][0]))
