from .step import (
    TrainConfig,
    TrainProcess,
    batch_pspecs,
    make_train_state,
    make_train_step,
    state_pspecs,
    to_named,
)
from .trainer import StepTimeout, Trainer, TrainerConfig

__all__ = ["StepTimeout", "TrainConfig", "TrainProcess", "Trainer",
           "TrainerConfig", "batch_pspecs", "make_train_state",
           "make_train_step", "state_pspecs", "to_named"]
