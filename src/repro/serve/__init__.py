from .engine import ServeEngine, SamplingConfig, make_decode_fn, make_prefill_fn
from .pipeline import PipelineServer, ServeResponse

__all__ = ["PipelineServer", "SamplingConfig", "ServeEngine",
           "ServeResponse", "make_decode_fn", "make_prefill_fn"]
