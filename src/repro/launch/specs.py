"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``build_lowerable(arch, shape)`` returns everything ``dryrun.py`` needs:
the step function, example specs (no allocation), and in/out shardings.
This is the single source of truth for how each family's train / prefill /
decode step is shaped and sharded on the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config, shape_applicable
from repro.models import build_model
from repro.models.common import ArchConfig, BATCH_AXES, MODEL, partition_tree
from repro.train import TrainConfig, batch_pspecs, make_train_state, make_train_step, state_pspecs
from repro.optim import AdamWConfig

#: whisper: fixed encoder length (30 s of audio -> 1500 frames)
WHISPER_ENC_FRAMES = 1500


def spec_tree(tree) -> Any:
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), a.dtype), tree)


def default_microbatches(cfg: ArchConfig, shape: ShapeSpec, mesh_data: int = 16,
                         budget_bytes: float = 2e9) -> int:
    """Grad-accum factor so the remat-saved activations (~L x tokens x d x 2B
    per data shard, x2 for MoE dispatch buffers / SSM conv+state streams)
    stay under ``budget_bytes`` (~1/8 of v5e HBM, leaving room for params,
    optimizer shards, gradients and transients)."""
    if shape.kind != "train":
        return 1
    rows = max(1, shape.batch // mesh_data)
    width = cfg.d_model * (2 if cfg.family in ("hybrid", "moe") else 1)
    est = cfg.n_layers * rows * shape.seq * width * 2
    mb = 1
    while est / mb > budget_bytes and mb < min(16, rows):
        mb *= 2
    return mb


@dataclasses.dataclass
class Lowerable:
    arch: str
    shape: str
    kind: str
    fn: Callable              # the pure step function
    specs: Tuple[Any, ...]    # ShapeDtypeStructs, one per arg
    in_pspecs: Tuple[Any, ...]
    out_pspecs: Any           # or None
    donate: Tuple[int, ...] = ()
    note: str = ""


def _train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.batch, shape.seq
    i32 = jnp.int32
    if cfg.family == "encdec":
        # split the token budget: half encoder frames, half decoder tokens
        half = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), cfg.adtype),
            "tokens": jax.ShapeDtypeStruct((b, half), i32),
            "labels": jax.ShapeDtypeStruct((b, half), i32),
        }
    if cfg.family == "vlm":
        # patch prefix + text fills the remaining positions
        text = s - cfg.n_patches
        return {
            "patch_embeds": jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), cfg.adtype),
            "tokens": jax.ShapeDtypeStruct((b, text), i32),
            "labels": jax.ShapeDtypeStruct((b, text), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }


def _state_specs(model, cfg: ArchConfig, compress: bool = False):
    params = jax.eval_shape(model.init_params, jax.random.key(0))

    def opt_of(p):
        from repro.optim import adamw_init
        return adamw_init(p)

    state = {"params": params, "opt": jax.eval_shape(opt_of, params)}
    if compress:
        state["ef"] = jax.eval_shape(
            lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p), params)
    return state


def _cache_specs(model, cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return jax.eval_shape(lambda: model.init_cache(batch, max_len, WHISPER_ENC_FRAMES))
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def _cache_pspecs(model, cache_specs):
    rules = model.cache_partition_rules()
    return partition_tree(cache_specs, rules)


def build_lowerable(arch: str, shape_name: str, *,
                    microbatches: Optional[int] = None,
                    compress_grads: bool = False,
                    zero1: bool = True,
                    cfg_override: Optional[ArchConfig] = None) -> Lowerable:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    model = build_model(cfg)

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else default_microbatches(cfg, shape)
        tcfg = TrainConfig(microbatches=mb, compress_grads=compress_grads,
                           opt=AdamWConfig())
        step = make_train_step(model, tcfg)
        state_specs = _state_specs(model, cfg, compress=compress_grads)
        batch_specs = _train_batch_specs(cfg, shape)
        sspec = state_pspecs(model, state_specs)
        if not zero1:
            sspec = {  # plain replicated-over-data optimizer
                "params": sspec["params"],
                "opt": {"master": sspec["params"], "m": sspec["params"],
                        "v": sspec["params"], "step": P()},
                **({"ef": sspec["params"]} if "ef" in sspec else {}),
            }
        bspec = batch_pspecs(batch_specs)
        return Lowerable(
            arch=arch, shape=shape_name, kind="train", fn=step,
            specs=(state_specs, batch_specs),
            in_pspecs=(sspec, bspec), out_pspecs=(sspec, None),
            donate=(0,), note=f"microbatches={mb} zero1={zero1}")

    params_specs = jax.eval_shape(model.init_params, jax.random.key(0))
    prules = model.partition_rules()
    pspec = partition_tree(params_specs, prules)

    if shape.kind == "prefill":
        cache_specs = _cache_specs(model, cfg, shape.batch, shape.seq)
        cspec = _cache_pspecs(model, cache_specs)
        if cfg.family == "encdec":
            fn = lambda p, frames, toks, c: model.prefill(p, frames, toks, c)
            half = WHISPER_ENC_FRAMES
            specs = (params_specs,
                     jax.ShapeDtypeStruct((shape.batch, half, cfg.d_model), cfg.adtype),
                     jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32),
                     cache_specs)
            in_pspecs = (pspec, P(BATCH_AXES, None, None), P(BATCH_AXES, None), cspec)
        else:
            fn = lambda p, toks, c: model.prefill(p, toks, c)
            specs = (params_specs,
                     jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32),
                     cache_specs)
            in_pspecs = (pspec, P(BATCH_AXES, None), cspec)
        return Lowerable(
            arch=arch, shape=shape_name, kind="prefill", fn=fn, specs=specs,
            in_pspecs=in_pspecs, out_pspecs=(None, cspec),
            donate=(len(specs) - 1,))

    # decode: one new token against a seq_len-deep cache
    cache_specs = _cache_specs(model, cfg, shape.batch, shape.seq)
    cspec = _cache_pspecs(model, cache_specs)
    fn = lambda p, tok, pos, c: model.decode_step(p, tok, pos, c)
    specs = (params_specs,
             jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32),
             jax.ShapeDtypeStruct((), jnp.int32),
             cache_specs)
    in_pspecs = (pspec, P(BATCH_AXES, None), P(), cspec)
    return Lowerable(
        arch=arch, shape=shape_name, kind="decode", fn=fn, specs=specs,
        in_pspecs=in_pspecs, out_pspecs=(None, cspec), donate=(3,))


def input_specs(arch: str, shape_name: str, **kw) -> Tuple[Any, ...]:
    """Paper-interface helper: the ShapeDtypeStruct stand-ins for a cell."""
    return build_lowerable(arch, shape_name, **kw).specs


def named_shardings(pspec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        pspec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def fit_pspec(spec: P, shape, mesh_shape: Dict[str, int]) -> P:
    """pjit ARGUMENT shardings must divide dims exactly (intermediates get
    GSPMD padding, arguments do not).  Keep the largest prefix of each dim's
    axis tuple that divides; drop the rest (-> replication on that dim).
    E.g. vocab=49155 over 16 'model' shards -> replicated; batch=1 decode
    over ('pod','data') -> replicated."""
    if not isinstance(spec, P):
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, (tuple, list)) else (e,)
        keep, cur = [], 1
        for a in axes:
            if dim % (cur * mesh_shape[a]) == 0:
                keep.append(a)
                cur *= mesh_shape[a]
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def fit_pspecs(pspec_tree, specs_tree, mesh) -> Any:
    """Leaf-wise fit of a PartitionSpec tree against ShapeDtypeStructs."""
    mesh_shape = dict(mesh.shape)
    return jax.tree.map(
        lambda sds, s: fit_pspec(s, sds.shape, mesh_shape),
        specs_tree, pspec_tree,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))
