"""Shared fixtures.  ``hypothesis`` is optional: network-less containers
cannot install it, so when it is missing a minimal stand-in module is
registered that auto-skips every ``@given`` test (and accepts any strategy
expression) instead of killing collection with an ImportError."""
import sys
import types

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    _strategies = types.ModuleType("hypothesis.strategies")
    # any strategy constructor (st.lists, st.integers, ...) -> opaque object
    _strategies.__getattr__ = lambda name: (lambda *a, **k: None)

    _hypothesis = types.ModuleType("hypothesis")
    _hypothesis.given = _given
    _hypothesis.strategies = _strategies
    _hypothesis.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None)
    _hypothesis.settings = types.SimpleNamespace(
        register_profile=lambda *a, **k: None,
        load_profile=lambda *a, **k: None)
    sys.modules["hypothesis"] = _hypothesis
    sys.modules["hypothesis.strategies"] = _strategies
else:
    # CPU in this container is slow and single-core; disable deadlines globally.
    settings.register_profile(
        "repro", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
    settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
