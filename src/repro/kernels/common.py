"""Shared helpers for Pallas TPU kernels.

All kernels target TPU (``pl.pallas_call`` + explicit ``BlockSpec`` VMEM
tiling) and are *validated* on CPU in interpret mode — the kernel body runs
in Python with the same blocking/grid semantics.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Large-negative float32 used instead of -inf so fully-masked rows degrade to
# finite garbage (they only occur in padding, which wrappers slice away)
# instead of NaN-poisoning the accumulator.
NEG_INF = -1.0e30

# TPU tiling constants: MXU is 128x128, VPU lanes are 8x128.
LANE = 128
SUBLANE = 8


def interpret_mode() -> bool:
    """Pallas must interpret on non-TPU backends; real lowering on TPU."""
    return jax.default_backend() != "tpu"


def round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def pad_dim(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to length ``target``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads)


def split_complex(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Complex -> (re, im) float pair (TPU Pallas has no complex dtype)."""
    if jnp.iscomplexobj(x):
        return jnp.real(x), jnp.imag(x)
    return x, jnp.zeros_like(x)


def merge_complex(re: jax.Array, im: jax.Array) -> jax.Array:
    return jax.lax.complex(re.astype(jnp.float32), im.astype(jnp.float32))
