"""Fused RMSNorm Pallas kernel (LM hot path: 2 reads + 1 write, no f32
intermediate round-trip through HBM).

Rows are tiled over the grid; the full feature axis lives in one VMEM tile
(d_model <= ~8k for every assigned arch -> <= 32 KiB f32 per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.registry import kernel
from . import ref
from .common import SUBLANE, interpret_mode, pad_dim, round_up


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
            block_rows: int = 256) -> jax.Array:
    """x: (..., D); weight: (D,).  Matches ``ref.rmsnorm``."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    br = min(block_rows, round_up(max(rows, 1), SUBLANE))
    rp = round_up(max(rows, 1), br)
    xr = pad_dim(xr, 0, rp)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, d), x.dtype),
        interpret=interpret_mode(),
    )(xr, weight)
    return out[:rows].reshape(shape)


kernel("rmsnorm", ref=ref.rmsnorm)(rmsnorm)
