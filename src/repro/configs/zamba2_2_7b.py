"""zamba2-2.7b: 54 Mamba2 layers d=2560 (state 64, head 64) + one SHARED
attention block (32H kv=32, head 80; mlp ff=10240) applied every 6 layers;
vocab=32000.  [arXiv:2411.15242]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    attn_every=6, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab=128, ssm_state=16, ssm_head_dim=8, ssm_chunk=8, attn_every=2,
    param_dtype="float32", dtype="float32",
)
