"""Streaming executor: double-buffered transfers + batched launches.

The paper's overhead story (§III-A.2) is that OpenCLIPER hides transfer
housekeeping with pinned-memory buffer mapping so host↔device traffic can
overlap compute.  The single-shot ``init()/launch()`` path reproduced in
:mod:`repro.core.process` is still fully synchronous per Data set: pack,
``device_put``, launch, repeat.  This module makes process chains
production-shaped for many independent Data sets (MRI slice stacks,
inference requests):

* :class:`StreamQueue` — a bounded prefetching host→device feed.  While
  batch *i* executes, batch *i+1*'s arena blob is already in flight via an
  asynchronously dispatched ``jax.device_put``; ``block_until_ready`` only
  happens at explicit sync points (never per item).

* :class:`BatchedProcess` — AOT-compiles a process's
  :class:`~repro.core.process.PureLaunchable` ONCE for a leading batch
  axis: ``vmap`` over the arena-blob unpack/compute/pack, aux blobs
  broadcast.  k independent Data sets become one launch instead of a
  Python loop of k launches.  Reuses the global compile cache (the batch
  size is part of the spec key) and the donation rule (in-place programs
  donate the stacked input blob — always a transfer temporary, so donation
  is safe by construction).

* :func:`stream_launch` — the engine behind ``Process.stream(datasets,
  batch=k)``: pack host-side, group into batches (the last batch is padded
  by repetition so a ragged tail never triggers a second compile), feed
  through a StreamQueue, launch batched, and scatter the per-item output
  blobs into fresh output Data objects.

Results are bit-identical to sequential ``launch()`` — the vmapped program
runs the same per-item computation, only batched (verified in
tests/test_stream.py and benchmarks/stream_throughput.py).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Iterable, Iterator, List, Optional, Sequence

import jax
import numpy as np

from .arena import batched_spec, stack_host_blobs
from .data import Data
from .process import PureLaunchable, ProfileParameters, aot_compile
from .sync import Coherence


class StreamQueue:
    """Bounded, double-buffered host→device transfer queue.

    Wraps an iterator of host blobs (numpy arrays).  Up to ``depth`` items
    are dispatched ahead with ``jax.device_put`` (asynchronous — JAX only
    blocks a *reader* of the array); consuming item *i* immediately starts
    the transfer of item *i+depth*.  ``depth=2`` is classic double
    buffering; larger depths trade memory for more dispatch-ahead slack.
    """

    def __init__(self, items: Iterable[np.ndarray], device=None, depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._it = iter(items)
        self._device = device
        self._depth = depth
        self._fifo: deque = deque()
        self._exhausted = False
        self.transfers = 0  # number of device_puts issued (introspection)

    def _fill(self) -> None:
        while not self._exhausted and len(self._fifo) < self._depth:
            try:
                item = next(self._it)
            except StopIteration:
                self._exhausted = True
                return
            self._fifo.append(jax.device_put(item, self._device))
            self.transfers += 1

    def __iter__(self) -> Iterator[jax.Array]:
        return self

    def __next__(self) -> jax.Array:
        self._fill()
        if not self._fifo:
            raise StopIteration
        out = self._fifo.popleft()
        self._fill()  # start the next transfer before the caller computes
        return out

    def sync(self) -> None:
        """Explicit sync point: block until every in-flight blob has landed."""
        for blob in self._fifo:
            jax.block_until_ready(blob)


class BatchedProcess:
    """A process AOT-compiled once for a leading batch axis.

    ``fn(blob, *aux) -> blob`` becomes ``vmap(fn)((k, nbytes) blobs, aux
    broadcast)``; compilation goes through :func:`~repro.core.process.
    aot_compile`, so repeated construction for the same process/batch size
    hits the global compile cache (the paper's "init once" at batch scale).
    """

    def __init__(self, process, batch: int):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.process = process
        self.batch = batch
        self.launchable: Optional[PureLaunchable] = None
        self._compiled = None

    def init(self) -> "BatchedProcess":
        p = self.process
        app = p.getApp()
        for name in p.kernel_names:
            app.kernels.load(name)
        la = p.launchable()
        batched = jax.vmap(la.fn, in_axes=(0,) + (None,) * len(la.aux_handles))
        specs = [batched_spec(la.in_layout, self.batch)] + p._aux_specs(la)
        self._compiled = aot_compile(
            batched, specs,
            tag=f"{la.tag}@vmap",
            donate_argnums=(0,) if la.in_place else (),
            static_key=la.static_key,
            mesh=app.mesh,
        )
        self.launchable = la
        return self

    def __call__(self, stacked_blob: jax.Array,
                 aux_blobs: Sequence[jax.Array]) -> jax.Array:
        """One launch for ``batch`` independent Data sets.  Asynchronous —
        the caller decides when (whether) to block on the result."""
        if self._compiled is None:
            self.init()
        return self._compiled(stacked_blob, *aux_blobs)


def _host_blob_of(data: Data) -> np.ndarray:
    """Authoritative host blob of one input Data (syncing device→host first
    if only the device copy is fresh)."""
    if data.layout is None:
        data.plan()
    if any(a.host is None for a in data):
        data.sync_to_host()  # raises if there is no device copy either
    return data.pack_host()


def _batched_host_blobs(datasets: Sequence[Data], layout,
                        batch: int) -> Iterator[np.ndarray]:
    """Yield (batch, nbytes) stacked host blobs; the ragged tail is padded
    by repeating the last item (padded outputs are dropped downstream)."""
    group: List[np.ndarray] = []
    for d in datasets:
        if d.layout is None:
            d.plan()
        if d.layout != layout:
            raise ValueError(
                f"dataset layout {d.layout} does not match the wired input "
                f"layout {layout}; all streamed Data sets must be homogeneous")
        group.append(_host_blob_of(d))
        if len(group) == batch:
            yield stack_host_blobs(group, layout)
            group = []
    if group:
        group += [group[-1]] * (batch - len(group))
        yield stack_host_blobs(group, layout)


def stream_launch(process, datasets: Sequence[Data], *, batch: int = 1,
                  depth: int = 2, sync: bool = False,
                  profile: ProfileParameters | None = None) -> List[Data]:
    """Run ``datasets`` through ``process`` batched + double-buffered.

    See :meth:`repro.core.process.Process.stream` for the public contract.
    """
    datasets = list(datasets)
    if not datasets:
        return []
    app = process.getApp()
    bp = BatchedProcess(process, batch).init()
    la = bp.launchable

    aux_blobs = []
    for h in la.aux_handles:
        d = app.getData(h)
        if d.device_blob is None:
            # dispatch-only upload: the aux transfer rides alongside the
            # first input batch's transfer; the launch consuming the blob is
            # the implicit sync point (CLapp tracks the handle in flight)
            app.host2device(h, wait=False)
        aux_blobs.append(d.device_blob)

    queue = StreamQueue(_batched_host_blobs(datasets, la.in_layout, batch),
                        device=app.device, depth=depth)
    t0 = time.perf_counter()
    out_batches: List[jax.Array] = []
    for dev_batch in queue:           # batch i+1 transfers while i computes
        out_batches.append(bp(dev_batch, aux_blobs))
    # settle the aux uploads' coherence bookkeeping: by now every launch has
    # consumed the aux blobs, so this only waits on the transfers themselves
    app.wait_transfers(la.aux_handles)

    results: List[Data] = []
    for i in range(len(datasets)):
        out = Data.from_layout(la.out_layout)
        out.device_blob = out_batches[i // batch][i % batch]
        out.coherence = Coherence.DEVICE_FRESH
        results.append(out)
    if sync:
        for r in results:
            r.sync_to_host()          # np.asarray blocks per result
    if profile is not None and profile.enable:
        jax.block_until_ready([r.device_blob for r in results])
        profile.record(time.perf_counter() - t0)
    return results
